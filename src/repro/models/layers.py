"""Building blocks for the unified architecture zoo.

Every block comes in three parts sharing one source of truth:

* ``*_template(cfg)`` — a flat dict ``name -> ParamSpec(shape, axes, init)``
  describing parameters.  ``axes`` are *logical* axis names resolved to
  mesh axes by ``repro.distributed.sharding`` (single source of truth for
  both initialization and partitioning).
* ``*_apply(params, cfg, x, ...)`` — full-sequence forward (train/prefill).
* ``*_decode(params, cfg, x, cache, ...)`` — single-token forward with a
  recurrent/KV state, returning ``(y, new_cache)``.

Numerics policy: parameters and activations are ``cfg.jdtype`` (bf16 by
default); every matmul accumulates in fp32 (``preferred_element_type``);
norms / softmax / recurrences run in fp32 and cast back.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ArchConfig
from repro.models.partition import constrain

f32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical axes, len == ndim
    init: str = "normal"  # "normal" | "zeros" | "ones"
    scale: Optional[float] = None  # None => 1/sqrt(fan_in)

    def initializer(self, key, dtype):
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
        scale = self.scale if self.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape, f32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def dot(x, w):
    """Matmul with fp32 accumulation, output in x.dtype."""
    return jnp.einsum("...d,df->...f", x, w,
                      preferred_element_type=f32).astype(x.dtype)


def rms_norm(x, scale, eps):
    x32 = x.astype(f32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(f32))).astype(x.dtype)


def softcap(x, cap):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x, positions, theta):
    """Rotary embedding, half-rotation convention (llama/gemma).

    x: (B, S, ..., head_dim) with any number of middle (head) dims;
    positions: (B, S) absolute positions.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=f32) / half)
    ang = positions[..., None].astype(f32) * freq  # (B, S, half)
    extra = x.ndim - positions.ndim - 1  # head dims to broadcast over
    ang = ang.reshape(ang.shape[:-1] + (1,) * extra + (half,))
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half].astype(f32), x[..., half:].astype(f32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _attn_scores(q, k, scale, cap):
    # q: (B, S, K, G, hd), k: (B, T, K, hd) -> (B, K, G, S, T)
    s = jnp.einsum("bskgd,btkd->bkgst", q, k, preferred_element_type=f32)
    return softcap(s * scale, cap)


def _attn_out(p, v):
    # p: (B, K, G, S, T) fp32, v: (B, T, K, hd)
    return jnp.einsum("bkgst,btkd->bskgd", p.astype(v.dtype), v,
                      preferred_element_type=f32)


def attention(q, k, v, *, q_positions, kv_positions, causal=True,
              window=None, softcap_val=None, chunk_q=0, chunk_kv=0):
    """Masked multi-query attention (GQA layout).

    q: (B, S, K, G, hd); k, v: (B, T, K, hd).
    q_positions: (B, S) absolute positions of queries.
    kv_positions: (B, T) absolute positions of keys (-1 = invalid slot).
    window: if set, keys with q_pos - k_pos >= window are masked (local).
    chunk_q/chunk_kv: if >0 use the memory-efficient online-softmax path.
    """
    if chunk_q and chunk_kv and q.shape[1] > 1:
        return _chunked_attention(q, k, v, q_positions=q_positions,
                                  kv_positions=kv_positions, causal=causal,
                                  window=window, softcap_val=softcap_val,
                                  chunk_q=chunk_q, chunk_kv=chunk_kv)
    scale = q.shape[-1] ** -0.5
    s = _attn_scores(q, k, scale, softcap_val)  # (B,K,G,S,T) fp32
    mask = _attn_mask(q_positions, kv_positions, causal, window)
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = _attn_out(p, v)
    return o.astype(q.dtype)


def _attn_mask(q_pos, kv_pos, causal, window):
    # (B, S, T) boolean validity
    qp = q_pos[:, :, None].astype(jnp.int32)
    kp = kv_pos[:, None, :].astype(jnp.int32)
    mask = kp >= 0
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    return mask


def _chunked_attention(q, k, v, *, q_positions, kv_positions, causal,
                       window, softcap_val, chunk_q, chunk_kv):
    """Online-softmax attention, O(chunk_q * chunk_kv) score memory.

    Mirrors the Pallas flash kernel (kernels/flash_attention.py); this is
    the XLA-path equivalent used for long-sequence prefill.
    """
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    scale = hd ** -0.5
    cq = min(chunk_q, S)
    ckv = min(chunk_kv, T)
    nq, nkv = -(-S // cq), -(-T // ckv)
    pad_q, pad_kv = nq * cq - S, nkv * ckv - T

    qp = jnp.pad(q_positions, ((0, 0), (0, pad_q)))
    kvp = jnp.pad(kv_positions, ((0, 0), (0, pad_kv)), constant_values=-1)
    q_ = jnp.pad(q, ((0, 0), (0, pad_q)) + ((0, 0),) * 3)
    k_ = jnp.pad(k, ((0, 0), (0, pad_kv)) + ((0, 0),) * 2)
    v_ = jnp.pad(v, ((0, 0), (0, pad_kv)) + ((0, 0),) * 2)

    q_ = q_.reshape(B, nq, cq, K, G, hd)
    k_ = k_.reshape(B, nkv, ckv, K, hd)
    v_ = v_.reshape(B, nkv, ckv, K, hd)
    qp = qp.reshape(B, nq, cq)
    kvp = kvp.reshape(B, nkv, ckv)

    def q_chunk(qi, q_blk, qp_blk):
        # online softmax over kv chunks
        def kv_step(carry, inp):
            acc, m, l = carry
            k_blk, v_blk, kp_blk = inp
            s = _attn_scores(q_blk, k_blk, scale, softcap_val)  # (B,K,G,cq,ckv)
            mask = _attn_mask(qp_blk, kp_blk, causal, window)
            s = jnp.where(mask[:, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=f32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, K, G, cq, hd), f32)
        m0 = jnp.full((B, K, G, cq), -jnp.inf, f32)
        l0 = jnp.zeros((B, K, G, cq), f32)
        (acc, m, l), _ = lax.scan(
            kv_step, (acc0, m0, l0),
            (k_.swapaxes(0, 1), v_.swapaxes(0, 1), kvp.swapaxes(0, 1)))
        o = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bkgsd->bskgd", o).astype(q.dtype)

    # remat each q-chunk: backward recomputes its kv scan instead of
    # stashing (bq x bkv) score tiles per kv step
    out = lax.map(lambda args: jax.checkpoint(q_chunk)(*args),
                  (jnp.arange(nq), q_.swapaxes(0, 1), qp.swapaxes(0, 1)))
    out = out.swapaxes(0, 1).reshape(B, nq * cq, K, G, hd)
    return out[:, :S]


def cache_write(cache, new, pos):
    """Write per-sequence entries into a cache at per-sequence positions.

    cache: (B, S, ...); new: (B, ...); pos: (B,) int32. Returns updated cache.
    """
    def upd(c, n, p):
        return lax.dynamic_update_slice(c, n[None].astype(c.dtype),
                                        (p,) + (0,) * (c.ndim - 1))
    return jax.vmap(upd)(cache, new, pos)


def causal_conv1d(x, w, b, state=None):
    """Depthwise causal temporal conv.

    x: (B, S, D); w: (W, D); b: (D,).  state: (B, W-1, D) history or None.
    Returns (y, new_state) where new_state holds the trailing W-1 inputs.
    """
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+W-1, D)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return (y + b).astype(x.dtype), new_state


# --------------------------------------------------------------------------
# attention block (dense / local / cross) — shared by most families
# --------------------------------------------------------------------------

def attn_template(cfg: ArchConfig, *, cross=False, heads=None, kv_heads=None):
    D, hd = cfg.d_model, cfg.head_dim
    H = heads or cfg.n_heads
    K = kv_heads or cfg.n_kv_heads
    t = {
        "wq": ParamSpec((D, H * hd), ("embed", "heads")),
        "wk": ParamSpec((D, K * hd), ("embed", "kv_heads")),
        "wv": ParamSpec((D, K * hd), ("embed", "kv_heads")),
        "wo": ParamSpec((H * hd, D), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((H * hd,), ("heads",), init="zeros")
        t["bk"] = ParamSpec((K * hd,), ("kv_heads",), init="zeros")
        t["bv"] = ParamSpec((K * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        t["q_norm"] = ParamSpec((hd,), (None,), init="zeros")
        t["k_norm"] = ParamSpec((hd,), (None,), init="zeros")
    return t


def _project_qkv(p, cfg, x, *, heads=None, kv_heads=None):
    H = heads or cfg.n_heads
    K = kv_heads or cfg.n_kv_heads
    hd = cfg.head_dim
    B, S, _ = x.shape
    q, k, v = dot(x, p["wq"]), dot(x, p["wk"]), dot(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if S > 1:
        # head-sharded / seq-gathered attention layout: one all-gather
        # per layer here instead of one per (q-chunk, kv-chunk) tile
        # inside the online-softmax loops (fused dims always divide)
        q = constrain(q, "batch", None, "heads")
        k = constrain(k, "batch", None, "kv_heads")
        v = constrain(v, "batch", None, "kv_heads")
    q = q.reshape(B, S, K, H // K, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def attn_apply(p, cfg, x, positions, *, kind="attn_global", heads=None,
               kv_heads=None, encoder_kv=None, make_cache=0):
    """Full-sequence attention.  Returns (y, cache|None).

    kind: attn_global | attn_local | attn_bidir | attn_cross.
    make_cache: if >0, emit a decode cache of that many slots.
    """
    B, S, _ = x.shape
    H = heads or cfg.n_heads
    K = kv_heads or cfg.n_kv_heads
    hd = cfg.head_dim
    q, k, v = _project_qkv(p, cfg, x, heads=heads, kv_heads=kv_heads)

    use_chunks = S > cfg.attn_chunk_threshold
    cq = cfg.attn_chunk_q if use_chunks else 0
    ckv = cfg.attn_chunk_kv if use_chunks else 0
    if kind == "attn_cross":
        ek, ev = encoder_kv
        kv_pos = jnp.broadcast_to(jnp.arange(ek.shape[1]), (B, ek.shape[1]))
        if cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
        o = attention(q, ek, ev, q_positions=positions, kv_positions=kv_pos,
                      causal=False, chunk_q=cq, chunk_kv=ckv)
    else:
        causal = kind != "attn_bidir"
        window = cfg.window_size if kind == "attn_local" else None
        if causal and cfg.use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        o = attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=causal, window=window, softcap_val=cfg.attn_softcap,
            chunk_q=cq, chunk_kv=ckv)

    y = dot(o.reshape(B, S, H * hd), p["wo"])

    cache = None
    if make_cache and kind in ("attn_global", "attn_local"):
        slots = make_cache if kind == "attn_global" else min(
            make_cache, cfg.window_size)
        n = min(S, slots)
        tail_pos = positions[:, S - n:]
        kt, vt = k[:, S - n:], v[:, S - n:]
        quant = cfg.kv_quant == "int8" and kind == "attn_global"
        if quant:
            kt, ks = kv_quantize(kt)
            vt, vs = kv_quantize(vt)
        if kind == "attn_global" or n < slots:
            # global caches are position-indexed and prefill starts at
            # position 0, so the tail maps to slots [0, n) — a plain pad,
            # no scatter (scatters shard poorly and copy the cache)
            pad = ((0, 0), (0, slots - n), (0, 0), (0, 0))
            ck, cv = jnp.pad(kt, pad), jnp.pad(vt, pad)
            cp = jnp.pad(tail_pos, ((0, 0), (0, slots - n)),
                         constant_values=-1)
            if quant:
                ks = jnp.pad(ks, ((0, 0), (0, slots - n), (0, 0)))
                vs = jnp.pad(vs, ((0, 0), (0, slots - n), (0, 0)))
        else:
            # full local ring buffer: slot = position % window, which for
            # the last `slots` positions is a cyclic roll of the tail
            shift = tail_pos[0, 0] % slots  # uniform prefill positions
            ck = jnp.roll(kt, shift, axis=1)
            cv = jnp.roll(vt, shift, axis=1)
            cp = jnp.roll(tail_pos, shift, axis=1)
        cache = {"k": ck, "v": cv, "pos": cp}
        if quant:
            cache["k_scale"] = ks
            cache["v_scale"] = vs
    return y, cache


def kv_quantize(t):
    """Per (token, kv-head) symmetric int8: t (B, S, K, hd) ->
    (int8 codes, f32 scales (B, S, K))."""
    amax = jnp.max(jnp.abs(t.astype(f32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(t.astype(f32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _attn_decode_quant(q, cache, *, window, softcap_val, q_positions):
    """Decode attention over an int8 KV cache.

    The dequantization scale is folded *around* the integer dots —
    k's scale rescales the score column, v's scale rescales p before the
    PV dot — so no bf16 copy of the cache ever materializes.
    """
    scale = q.shape[-1] ** -0.5
    kq, ks = cache["k"], cache["k_scale"]  # (B,T,K,hd) i8, (B,T,K) f32
    vq, vs = cache["v"], cache["v_scale"]
    s = jnp.einsum("bskgd,btkd->bkgst", q.astype(f32), kq.astype(f32))
    s = s * ks.transpose(0, 2, 1)[:, :, None, None, :] * scale
    s = softcap(s, softcap_val)
    mask = _attn_mask(q_positions, cache["pos"], True, window)
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = p * vs.transpose(0, 2, 1)[:, :, None, None, :]
    o = jnp.einsum("bkgst,btkd->bskgd", p, vq.astype(f32))
    return o.astype(q.dtype)


def attn_decode(p, cfg, x, positions, cache, *, kind="attn_global",
                heads=None, kv_heads=None, encoder_kv=None):
    """Single-token attention with KV cache.  x: (B, 1, D); positions: (B,).

    Global caches are position-indexed (slot = position); local caches are
    ring buffers (slot = position % window) with explicit slot positions.
    """
    B = x.shape[0]
    H = heads or cfg.n_heads
    K = kv_heads or cfg.n_kv_heads
    hd = cfg.head_dim
    q, k, v = _project_qkv(p, cfg, x, heads=heads, kv_heads=kv_heads)

    if kind == "attn_cross":
        ek, ev = encoder_kv
        kv_pos = jnp.broadcast_to(jnp.arange(ek.shape[1]), (B, ek.shape[1]))
        if cfg.use_rope:
            q = rope(q, positions[:, None], cfg.rope_theta)
        o = attention(q, ek, ev, q_positions=positions[:, None],
                      kv_positions=kv_pos, causal=False)
        return dot(o.reshape(B, 1, H * hd), p["wo"]), cache

    if cfg.use_rope:
        q = rope(q, positions[:, None], cfg.rope_theta)
        k = rope(k, positions[:, None], cfg.rope_theta)
    slots = cache["k"].shape[1]
    slot = positions % slots if kind == "attn_local" else positions
    window = cfg.window_size if kind == "attn_local" else None
    if "k_scale" in cache:  # int8 KV cache
        kq, ks = kv_quantize(k)
        vq, vs = kv_quantize(v)
        new_cache = {
            "k": cache_write(cache["k"], kq[:, 0], slot),
            "v": cache_write(cache["v"], vq[:, 0], slot),
            "k_scale": cache_write(cache["k_scale"], ks[:, 0], slot),
            "v_scale": cache_write(cache["v_scale"], vs[:, 0], slot),
            "pos": cache_write(cache["pos"], positions, slot),
        }
        o = _attn_decode_quant(q, new_cache, window=window,
                               softcap_val=cfg.attn_softcap,
                               q_positions=positions[:, None])
        return dot(o.reshape(B, 1, H * hd), p["wo"]), new_cache
    new_cache = {
        "k": cache_write(cache["k"], k[:, 0], slot),
        "v": cache_write(cache["v"], v[:, 0], slot),
        "pos": cache_write(cache["pos"], positions, slot),
    }
    o = attention(q, new_cache["k"], new_cache["v"],
                  q_positions=positions[:, None], kv_positions=new_cache["pos"],
                  causal=True, window=window, softcap_val=cfg.attn_softcap)
    return dot(o.reshape(B, 1, H * hd), p["wo"]), new_cache


# --------------------------------------------------------------------------
# gated MLP (dense) and MoE
# --------------------------------------------------------------------------

def mlp_template(cfg: ArchConfig, d_ff=None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": ParamSpec((D, 2 * F), ("embed", "ff")),  # fused gate+up
        "wo": ParamSpec((F, D), ("ff", "embed")),
    }


def mlp_apply(p, x):
    # pin the hidden to ff(model)-sharding: its cotangent then shards the
    # same way, which keeps dW_i = x^T @ d(hidden) ff-sharded instead of
    # replicated (a multi-GB fp32 buffer per period position otherwise)
    gu = constrain(dot(x, p["wi"]), "batch", None, "ff")
    g, u = jnp.split(gu, 2, axis=-1)
    h = constrain(jax.nn.gelu(g.astype(f32)).astype(x.dtype) * u,
                  "batch", None, "ff")
    return dot(h, p["wo"])


def moe_template(cfg: ArchConfig):
    D = cfg.d_model
    e = cfg.moe
    return {
        "router": ParamSpec((D, e.n_experts), ("embed", None)),
        "wi": ParamSpec((e.n_experts, D, 2 * e.d_expert_ff),
                        ("experts", "embed", "ff")),
        "wo": ParamSpec((e.n_experts, e.d_expert_ff, D),
                        ("experts", "ff", "embed")),
    }


def moe_apply(p, cfg, x, group_size=None):
    """Switch-style capacity-based MoE with grouped one-hot dispatch.

    x: (B, S, D).  Returns (y, aux) where aux carries the router load
    (per-expert probability mass — the Level-B utilization signal) and
    the load-balancing loss term.
    """
    e = cfg.moe
    B, S, D = x.shape
    N = B * S
    gs = min(group_size or cfg.moe_group, N)
    G = N // gs
    xg = x.reshape(G, gs, D)

    logits = jnp.einsum("gsd,de->gse", xg, p["router"],
                        preferred_element_type=f32)
    probs = jax.nn.softmax(logits, axis=-1)  # (G, S, E) fp32
    top_p, top_e = lax.top_k(probs, e.top_k)  # (G, S, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = max(int(e.capacity_factor * gs * e.top_k / e.n_experts), 1)
    onehot = jax.nn.one_hot(top_e, e.n_experts, dtype=f32)  # (G,S,k,E)
    # position of each (token, slot) within its expert queue
    flat = onehot.reshape(G, gs * e.top_k, e.n_experts)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(G, gs, e.top_k,
                                                    e.n_experts)
    keep = (pos < cap) * onehot
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=f32)
    disp = jnp.einsum("gske,gskec->gsec", keep, pos_oh)  # (G,S,E,C)
    comb = jnp.einsum("gsk,gske,gskec->gsec", top_p, keep, pos_oh)
    # dispatch tensors: token groups over DP, experts over the EP axis;
    # bf16 is plenty for one-hot routing masks and halves their footprint
    disp = constrain(disp.astype(x.dtype), "batch", None, "experts", None)
    comb = constrain(comb.astype(f32), "batch", None, "experts", None)

    xin = jnp.einsum("gsec,gsd->egcd", disp.astype(f32), xg.astype(f32),
                     preferred_element_type=f32).astype(x.dtype)
    xin = constrain(xin, "experts", "batch", None, "embed")
    gu = jnp.einsum("egcd,edf->egcf", xin, p["wi"],
                    preferred_element_type=f32).astype(x.dtype)
    g, u = jnp.split(gu, 2, axis=-1)
    h = jax.nn.gelu(g.astype(f32)).astype(x.dtype) * u
    hout = jnp.einsum("egcf,efd->egcd", h, p["wo"],
                      preferred_element_type=f32)
    y = jnp.einsum("gsec,egcd->gsd", comb, hout).astype(x.dtype)

    # aux: per-expert routed mass and Switch load-balancing loss
    load = onehot.sum((0, 1, 2)) / (N * e.top_k)  # fraction dispatched
    importance = probs.mean((0, 1))
    aux_loss = e.n_experts * jnp.sum(load * importance)
    aux = {"expert_load": load, "moe_aux_loss": aux_loss}
    return y.reshape(B, S, D), aux


# --------------------------------------------------------------------------
# RG-LRU recurrent block (recurrentgemma / Griffin)
# --------------------------------------------------------------------------

def rglru_template(cfg: ArchConfig):
    D = cfg.d_model
    R = cfg.rglru_dim or D
    W = cfg.conv_width
    return {
        "wx": ParamSpec((D, R), ("embed", "ff")),  # recurrence branch in
        "wg": ParamSpec((D, R), ("embed", "ff")),  # gate branch in
        "wo": ParamSpec((R, D), ("ff", "embed")),
        "conv_w": ParamSpec((W, R), (None, "ff"), scale=1.0 / W),
        "conv_b": ParamSpec((R,), ("ff",), init="zeros"),
        "lam": ParamSpec((R,), ("ff",), init="ones"),  # Λ (decay logits)
        "w_a": ParamSpec((R, R), ("ff", None)),  # recurrence gate r_t
        "w_i": ParamSpec((R, R), ("ff", None)),  # input gate i_t
    }


_RGLRU_C = 8.0  # Griffin's fixed decay temperature


def _rglru_coeffs(p, u):
    """Gates and log-decay for RG-LRU.  u: (B, S, R) post-conv input."""
    u32 = u.astype(f32)
    r = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", u32, p["w_a"].astype(f32)))
    i = jax.nn.sigmoid(jnp.einsum("bsr,rq->bsq", u32, p["w_i"].astype(f32)))
    log_a = -_RGLRU_C * r * jax.nn.softplus(p["lam"].astype(f32))  # (B,S,R)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * i * u32
    return a, gated


def rglru_apply(p, cfg, x, *, make_cache=False):
    """Full-sequence RG-LRU block via associative scan."""
    B, S, D = x.shape
    u = dot(x, p["wx"])
    gate = jax.nn.gelu(dot(x, p["wg"]).astype(f32)).astype(x.dtype)
    u, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"])
    a, gated = _rglru_coeffs(p, u)

    def combine(c1, c2):
        a1, h1 = c1
        a2, h2 = c2
        return a1 * a2, h1 * a2 + h2

    _, h = lax.associative_scan(combine, (a, gated), axis=1)
    y = dot((h.astype(x.dtype) * gate), p["wo"])
    cache = None
    if make_cache:
        cache = {"h": h[:, -1].astype(f32), "conv": conv_state}
    return y, cache


def rglru_decode(p, cfg, x, cache):
    """One-step RG-LRU.  x: (B, 1, D); cache: {"h": (B,R) f32, "conv"}."""
    u = dot(x, p["wx"])
    gate = jax.nn.gelu(dot(x, p["wg"]).astype(f32)).astype(x.dtype)
    u, conv_state = causal_conv1d(u, p["conv_w"], p["conv_b"],
                                  state=cache["conv"])
    a, gated = _rglru_coeffs(p, u)
    h = cache["h"] * a[:, 0] + gated[:, 0]  # (B, R)
    y = dot((h[:, None].astype(x.dtype) * gate), p["wo"])
    return y, {"h": h, "conv": conv_state}


# --------------------------------------------------------------------------
# xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory)
# --------------------------------------------------------------------------

def mlstm_template(cfg: ArchConfig):
    D = cfg.d_model
    nh = cfg.lru_heads or cfg.n_heads
    return {
        "wq": ParamSpec((D, D), ("embed", "heads")),
        "wk": ParamSpec((D, D), ("embed", "heads")),
        "wv": ParamSpec((D, D), ("embed", "heads")),
        "wi": ParamSpec((D, nh), ("embed", None), scale=0.1),
        "wf": ParamSpec((D, nh), ("embed", None), scale=0.1),
        "bf": ParamSpec((nh,), (None,), init="ones"),
        "wg": ParamSpec((D, D), ("embed", "heads")),  # output gate branch
        "wo": ParamSpec((D, D), ("heads", "embed")),
    }


def _mlstm_gates(p, x):
    x32 = x.astype(f32)
    i_log = jnp.einsum("bsd,dh->bsh", x32, p["wi"].astype(f32))
    f_log = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x32, p["wf"].astype(f32))
        + p["bf"].astype(f32))
    return i_log, f_log


def mlstm_apply(p, cfg, x, *, make_cache=False):
    """Stabilized mLSTM, sequential scan over time (fp32 state).

    State per head: C (dh, dh) matrix memory, n (dh,) normalizer, m scalar
    stabilizer.  h_t = o_t * (C_t q_t / max(|n_t.q_t|, 1)).
    """
    B, S, D = x.shape
    nh = cfg.lru_heads or cfg.n_heads
    dh = D // nh
    q = dot(x, p["wq"]).reshape(B, S, nh, dh).astype(f32) * dh ** -0.5
    k = dot(x, p["wk"]).reshape(B, S, nh, dh).astype(f32) * dh ** -0.5
    v = dot(x, p["wv"]).reshape(B, S, nh, dh).astype(f32)
    og = jax.nn.sigmoid(dot(x, p["wg"]).astype(f32)).reshape(B, S, nh, dh)
    i_log, f_log = _mlstm_gates(p, x)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, ot, il, fl = inp
        m_new = jnp.maximum(fl + m, il)
        i_ = jnp.exp(il - m_new)
        f_ = jnp.exp(fl + m - m_new)
        C = f_[..., None, None] * C + i_[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])
        n = f_[..., None] * n + i_[..., None] * kt
        num = jnp.einsum("bhij,bhj->bhi", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, qt)),
                          jnp.exp(-m_new))[..., None]
        h = ot * (num / den)
        return (C, n, m_new), h

    C0 = jnp.zeros((B, nh, dh, dh), f32)
    n0 = jnp.zeros((B, nh, dh), f32)
    m0 = jnp.zeros((B, nh), f32)
    xs = tuple(a.swapaxes(0, 1) for a in (q, k, v, og, i_log, f_log))
    (C, n, m), hs = lax.scan(step, (C0, n0, m0), xs)
    h = hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype)
    y = dot(h, p["wo"])
    cache = {"C": C, "n": n, "m": m} if make_cache else None
    return y, cache


def mlstm_decode(p, cfg, x, cache):
    B = x.shape[0]
    nh = cfg.lru_heads or cfg.n_heads
    dh = x.shape[-1] // nh
    q = dot(x, p["wq"]).reshape(B, nh, dh).astype(f32) * dh ** -0.5
    k = dot(x, p["wk"]).reshape(B, nh, dh).astype(f32) * dh ** -0.5
    v = dot(x, p["wv"]).reshape(B, nh, dh).astype(f32)
    og = jax.nn.sigmoid(dot(x, p["wg"]).astype(f32)).reshape(B, nh, dh)
    il, fl = _mlstm_gates(p, x)
    il, fl = il[:, 0], fl[:, 0]
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(fl + m, il)
    i_ = jnp.exp(il - m_new)
    f_ = jnp.exp(fl + m - m_new)
    C = f_[..., None, None] * C + i_[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n = f_[..., None] * n + i_[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)),
                      jnp.exp(-m_new))[..., None]
    h = (og * (num / den)).reshape(B, 1, -1).astype(x.dtype)
    return dot(h, p["wo"]), {"C": C, "n": n, "m": m_new}


def slstm_template(cfg: ArchConfig):
    D = cfg.d_model
    nh = cfg.lru_heads or cfg.n_heads
    dh = D // nh
    t = {}
    for g in ("i", "f", "z", "o"):
        t[f"w{g}"] = ParamSpec((D, D), ("embed", "heads"))
        t[f"r{g}"] = ParamSpec((nh, dh, dh), (None, None, None), scale=0.1)
        t[f"b{g}"] = ParamSpec((D,), ("heads",), init="zeros")
    t["wo_out"] = ParamSpec((D, D), ("heads", "embed"))
    return t


def slstm_apply(p, cfg, x, *, make_cache=False):
    """Stabilized sLSTM with block-diagonal recurrence (sequential scan)."""
    B, S, D = x.shape
    nh = cfg.lru_heads or cfg.n_heads
    dh = D // nh
    pre = {g: (dot(x, p[f"w{g}"]) + p[f"b{g}"]).astype(f32)
              .reshape(B, S, nh, dh) for g in ("i", "f", "z", "o")}
    R = {g: p[f"r{g}"].astype(f32) for g in ("i", "f", "z", "o")}

    def step(carry, inp):
        c, n, h, m = carry  # (B, nh, dh) each; m: (B, nh, dh)
        xi, xf, xz, xo = inp
        rec = {g: jnp.einsum("bhj,hij->bhi", h, R[g])
               for g in ("i", "f", "z", "o")}
        il = xi + rec["i"]
        fl = jax.nn.log_sigmoid(xf + rec["f"])
        m_new = jnp.maximum(fl + m, il)
        i_ = jnp.exp(il - m_new)
        f_ = jnp.exp(fl + m - m_new)
        z = jnp.tanh(xz + rec["z"])
        o = jax.nn.sigmoid(xo + rec["o"])
        c = f_ * c + i_ * z
        n = jnp.maximum(f_ * n + i_, jnp.exp(-m_new))
        h_new = o * c / n
        return (c, n, h_new, m_new), h_new

    zeros = jnp.zeros((B, nh, dh), f32)
    carry0 = (zeros, zeros + 1e-6, zeros, zeros)
    xs = tuple(pre[g].swapaxes(0, 1) for g in ("i", "f", "z", "o"))
    (c, n, h, m), hs = lax.scan(step, carry0, xs)
    y = dot(hs.swapaxes(0, 1).reshape(B, S, D).astype(x.dtype), p["wo_out"])
    cache = {"c": c, "n": n, "h": h, "m": m} if make_cache else None
    return y, cache


def slstm_decode(p, cfg, x, cache):
    B, _, D = x.shape
    nh = cfg.lru_heads or cfg.n_heads
    dh = D // nh
    pre = {g: (dot(x, p[f"w{g}"]) + p[f"b{g}"]).astype(f32)
              .reshape(B, nh, dh) for g in ("i", "f", "z", "o")}
    c, n, h, m = cache["c"], cache["n"], cache["h"], cache["m"]
    rec = {g: jnp.einsum("bhj,hij->bhi", h, p[f"r{g}"].astype(f32))
           for g in ("i", "f", "z", "o")}
    il = pre["i"] + rec["i"]
    fl = jax.nn.log_sigmoid(pre["f"] + rec["f"])
    m_new = jnp.maximum(fl + m, il)
    i_ = jnp.exp(il - m_new)
    f_ = jnp.exp(fl + m - m_new)
    z = jnp.tanh(pre["z"] + rec["z"])
    o = jax.nn.sigmoid(pre["o"] + rec["o"])
    c = f_ * c + i_ * z
    n = jnp.maximum(f_ * n + i_, jnp.exp(-m_new))
    h_new = o * c / n
    y = dot(h_new.reshape(B, 1, D).astype(x.dtype), p["wo_out"])
    return y, {"c": c, "n": n, "h": h_new, "m": m_new}
