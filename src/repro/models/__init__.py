"""Model zoo: one configurable implementation for all assigned archs."""

from repro.models.config import (  # noqa: F401
    ArchConfig, MoEConfig, SHAPES, ShapeSpec, applicable_shapes,
)
from repro.models.model import (  # noqa: F401
    block_pattern_of, decode_step, forward, init_cache, init_params,
    layer_layout, logical_axes, loss_fn, model_template, param_count,
    prefill,
)
from repro.models.inputs import input_specs, materialize  # noqa: F401
