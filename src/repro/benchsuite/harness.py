"""Cold-start measurement harness.

Each cold start is a fresh subprocess of ``repro.benchsuite.runner`` —
a faithful analog of a new Lambda container: cold module cache, cold
code objects, fresh heap.  Metrics are parsed from the runner's JSON
stdout and aggregated into mean / p99 statistics (the paper reports
both; p99 captures the tail that matters for SLAs).

Two execution modes:

* ``measure_cold_starts``  — fresh-process mode: every instance pays
  full interpreter boot + library init.
* ``measure_pool_starts``  — fork-pool mode: one zygote
  (:class:`repro.pool.forkserver.ForkServer`) pre-imports a hot set
  once, then every instance is a copy-on-write fork that only pays
  ``fork() + import handler``.  Same metrics shape, so the two modes
  compare directly (benchmarks/bench_pool_policies.py).
"""

from __future__ import annotations

import json
import math
import os
import statistics
import subprocess
import sys
from dataclasses import dataclass, field
from typing import Optional

_REPRO_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))


def _percentile(xs: list[float], q: float) -> float:
    if not xs:
        return math.nan
    ys = sorted(xs)
    idx = min(len(ys) - 1, max(0, int(round(q * (len(ys) - 1)))))
    return ys[idx]


@dataclass
class ColdStartStats:
    app: str
    n: int
    init_ms: list[float] = field(default_factory=list)
    e2e_ms: list[float] = field(default_factory=list)
    peak_rss_kb: list[float] = field(default_factory=list)

    @property
    def init_mean(self) -> float:
        return statistics.fmean(self.init_ms)

    @property
    def e2e_mean(self) -> float:
        return statistics.fmean(self.e2e_ms)

    @property
    def init_p99(self) -> float:
        return _percentile(self.init_ms, 0.99)

    @property
    def e2e_p99(self) -> float:
        return _percentile(self.e2e_ms, 0.99)

    @property
    def rss_mean_mb(self) -> float:
        return statistics.fmean(self.peak_rss_kb) / 1024.0

    def summary(self) -> dict:
        return {
            "app": self.app,
            "n": self.n,
            "init_mean_ms": self.init_mean,
            "init_p99_ms": self.init_p99,
            "e2e_mean_ms": self.e2e_mean,
            "e2e_p99_ms": self.e2e_p99,
            "rss_mean_mb": self.rss_mean_mb,
        }


def run_instance(app_dir: str, *, invocations: int = 1,
                 handler: Optional[str] = None, seed: int = 0,
                 profile: bool = False, sink: Optional[str] = None,
                 sample_interval: float = 0.002,
                 timeout_s: float = 120.0) -> dict:
    """One cold instance in a fresh subprocess; returns runner metrics."""
    cmd = [sys.executable, "-m", "repro.benchsuite.runner",
           "--app-dir", app_dir, "--invocations", str(invocations),
           "--seed", str(seed),
           "--sample-interval", str(sample_interval)]
    if handler:
        cmd += ["--handler", handler]
    if profile:
        cmd += ["--profile"]
        if sink:
            cmd += ["--sink", sink]
    env = dict(os.environ)
    env["PYTHONPATH"] = _REPRO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          timeout=timeout_s)
    if proc.returncode != 0:
        raise RuntimeError(
            f"runner failed for {app_dir}:\n{proc.stderr[-2000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


def measure_cold_starts(app_dir: str, n: int = 10, *,
                        handler: Optional[str] = None,
                        invocations: int = 1,
                        seed0: int = 100) -> ColdStartStats:
    """``n`` independent cold starts (fresh subprocess each)."""
    stats = ColdStartStats(app=os.path.basename(app_dir.rstrip("/")), n=n)
    for i in range(n):
        m = run_instance(app_dir, invocations=invocations, handler=handler,
                         seed=seed0 + i)
        stats.init_ms.append(m["init_ms"])
        stats.e2e_ms.append(m["e2e_cold_ms"])
        stats.peak_rss_kb.append(m["peak_rss_kb"])
    return stats


def measure_pool_starts(app_dir: str, n: int = 10, *,
                        preload: Optional[list[str]] = None,
                        handler: Optional[str] = None,
                        invocations: int = 1,
                        seed0: int = 100) -> ColdStartStats:
    """``n`` fork-pool warm starts through one zygote.

    ``preload`` is the zygote's pre-import hot set (e.g. from
    :func:`repro.pool.policies.hot_set_from_report`); ``None`` boots a
    bare zygote, which still amortizes interpreter + ``repro`` imports.
    """
    from repro.pool.forkserver import ForkServer
    stats = ColdStartStats(app=os.path.basename(app_dir.rstrip("/")), n=n)
    with ForkServer(app_dir, preload=preload or []) as fs:
        for i in range(n):
            m = fs.exec(invocations=invocations, handler=handler,
                        seed=seed0 + i)
            stats.init_ms.append(m["init_ms"])
            stats.e2e_ms.append(m["e2e_cold_ms"])
            stats.peak_rss_kb.append(m["peak_rss_kb"])
    return stats


def measure_warm_overhead(app_dir: str, *, invocations: int = 200,
                          seed: int = 7) -> tuple[float, float]:
    """Mean per-invocation time without and with the profiler attached
    (paper Fig. 9: runtime overhead of SLIMSTART-Profiler)."""
    base = run_instance(app_dir, invocations=invocations, seed=seed)
    prof = run_instance(app_dir, invocations=invocations, seed=seed,
                        profile=True)
    return base["mean_invoke_ms"], prof["mean_invoke_ms"]
