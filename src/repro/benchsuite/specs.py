"""Declarative specs for the synthetic benchmark suite.

Libraries are trees of modules with calibrated import-time CPU cost
(``spin_ms``) and import-time memory footprint (``alloc_mb``).  The
unused/rarely-used init fractions are sized so that deferring them
reproduces the paper's Table II initialization-speedup scale
(1.17× – 2.30×).  Applications mirror the paper's: the same library
roles (igraph for graph apps, nltk+textblob for sentiment, pandas for
wine-ml, …), multiple entry handlers with skewed invocation weights
(paper Fig. 3), and workload-dependent imports that static analysis
must keep but dynamic profiling can defer (paper Observation 2).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModSpec:
    spin_ms: float = 5.0  # CPU busy-work at import time
    alloc_mb: float = 1.0  # page-touched ballast held by the module
    imports: tuple[str, ...] = ()  # absolute dotted modules this imports
    use: tuple[str, ...] = ()  # imported bindings referenced in a function
    export: tuple[str, ...] = ()  # names re-exported via __all__


@dataclass(frozen=True)
class LibSpec:
    name: str
    modules: dict[str, ModSpec]  # "" is the package __init__

    def total_init_ms(self) -> float:
        return sum(m.spin_ms for m in self.modules.values())


@dataclass(frozen=True)
class HandlerSpec:
    name: str
    weight: float
    body: tuple[str, ...]  # statements; last value is returned


@dataclass(frozen=True)
class AppSpec:
    name: str
    paper_id: str  # e.g. "R-GB"
    suite: str  # rainbowcake | faaslight | faasworkbench | realworld | clean
    import_lines: tuple[str, ...]
    handlers: tuple[HandlerSpec, ...]
    # Dotted packages we EXPECT the profiler to flag (used by tests only;
    # the pipeline itself is entirely data-driven).
    expected_flagged: tuple[str, ...] = ()
    target_init_speedup: float = 1.0  # paper Table II, informational

    @property
    def hot_handler(self) -> str:
        return max(self.handlers, key=lambda h: h.weight).name

    @property
    def libs(self) -> tuple[str, ...]:
        seen = []
        for line in self.import_lines:
            for tok in line.replace(",", " ").split():
                if tok.startswith("fakelib_"):
                    root = tok.split(".")[0]
                    if root not in seen:
                        seen.append(root)
        return tuple(seen)


M = ModSpec

# ---------------------------------------------------------------------------
# Libraries
# ---------------------------------------------------------------------------

LIBS: dict[str, LibSpec] = {}


def _lib(name: str, modules: dict[str, ModSpec]) -> None:
    LIBS[name] = LibSpec(name, modules)


# -- graph processing (igraph analog; paper Table I shows its drawing
#    subtree being pulled in via clustering) ---------------------------------
_lib("fakelib_igraph", {
    "": M(4, 1, imports=("fakelib_igraph.core",
                         "fakelib_igraph.community",
                         "fakelib_igraph.drawing",
                         "fakelib_igraph.legacy"),
          use=("core",), export=("core", "community", "drawing")),
    "core": M(40, 8),
    "community": M(7, 1, imports=("fakelib_igraph.clustering",),
                   use=("clustering",)),
    "clustering": M(6, 1, imports=("fakelib_igraph.drawing.colors",),
                    use=("colors",)),
    "drawing": M(3, 1, imports=("fakelib_igraph.drawing.colors",
                                "fakelib_igraph.drawing.cairo",
                                "fakelib_igraph.drawing.matplotlib"),
                 use=("colors", "cairo", "matplotlib"),
                 export=("cairo", "matplotlib")),
    "drawing.colors": M(5, 1),
    "drawing.cairo": M(12, 4),
    "drawing.matplotlib": M(14, 5),
    # dead import in __init__ (binding unused, unexported): the slice
    # static analysis CAN catch.
    "legacy": M(6, 2),
})

# -- NLP (nltk analog; R-SA case study: sem/stem/parse/tag unused) ----------
_lib("fakelib_nltk", {
    "": M(5, 1, imports=("fakelib_nltk.tokenize", "fakelib_nltk.data",
                         "fakelib_nltk.corpus", "fakelib_nltk.sem",
                         "fakelib_nltk.stem", "fakelib_nltk.parse",
                         "fakelib_nltk.tag"),
          use=("tokenize", "data"),
          export=("tokenize", "corpus", "sem", "stem", "parse", "tag")),
    "tokenize": M(25, 4),
    "data": M(15, 6),
    "corpus": M(10, 3),
    "sem": M(8, 2),
    "stem": M(7, 2),
    "parse": M(6, 2),
    "tag": M(5, 1),
})

_lib("fakelib_textblob", {
    "": M(4, 1, imports=("fakelib_textblob.blob",
                         "fakelib_textblob.sentiments"),
          use=("blob", "sentiments"), export=("blob", "sentiments")),
    "blob": M(10, 2, imports=("fakelib_nltk",), use=("fakelib_nltk",)),
    "sentiments": M(8, 2),
})

# -- dataframes (pandas analog; wine-ml apps) --------------------------------
_lib("fakelib_pandas", {
    "": M(5, 2, imports=("fakelib_pandas.core", "fakelib_pandas.io",
                         "fakelib_pandas.api", "fakelib_pandas.plotting",
                         "fakelib_pandas.tseries", "fakelib_pandas.window",
                         "fakelib_pandas.computation"),
          use=("core", "io", "api"),
          export=("core", "io", "plotting", "tseries")),
    "core": M(30, 10),
    "io": M(15, 4),
    "api": M(4, 1),
    "plotting": M(20, 6),
    "tseries": M(12, 3),
    "window": M(6, 2),
    "computation": M(8, 2),
})

# -- arrays (numpy analog; R-DV: 2.30x -> ~57% deferrable) -------------------
_lib("fakelib_numpy", {
    "": M(4, 2, imports=("fakelib_numpy.core", "fakelib_numpy.linalg",
                         "fakelib_numpy.fft", "fakelib_numpy.polynomial",
                         "fakelib_numpy.random", "fakelib_numpy.ma",
                         "fakelib_numpy.testing"),
          use=("core", "linalg"),
          export=("core", "linalg", "fft", "random", "ma")),
    "core": M(30, 10),
    "linalg": M(8, 2),
    "fft": M(14, 4),
    "polynomial": M(12, 3),
    "random": M(16, 5),
    "ma": M(10, 3),
    "testing": M(6, 1),
})

# -- scientific computing (scipy analog) -------------------------------------
_lib("fakelib_scipy", {
    "": M(4, 1, imports=("fakelib_scipy._lib", "fakelib_scipy.optimize",
                         "fakelib_scipy.stats", "fakelib_scipy.sparse",
                         "fakelib_scipy.signal",
                         "fakelib_scipy.interpolate",
                         "fakelib_scipy.integrate"),
          use=("_lib", "optimize", "stats"),
          export=("optimize", "stats", "sparse", "signal", "integrate")),
    "_lib": M(8, 2),
    "optimize": M(30, 8),
    "stats": M(24, 6),
    "sparse": M(8, 3),
    "signal": M(7, 2),
    "interpolate": M(5, 2),
    "integrate": M(6, 2),
})

# -- image processing (skimage analog; depends on numpy) ---------------------
_lib("fakelib_skimage", {
    "": M(4, 1, imports=("fakelib_numpy", "fakelib_skimage.filters",
                         "fakelib_skimage.color",
                         "fakelib_skimage.morphology",
                         "fakelib_skimage.segmentation",
                         "fakelib_skimage.io"),
          use=("fakelib_numpy", "filters", "color"),
          export=("filters", "color", "morphology", "io")),
    "filters": M(18, 4),
    "color": M(10, 2),
    "morphology": M(12, 3),
    "segmentation": M(10, 3),
    "io": M(8, 2),
})

# -- ML (sklearn analog) ------------------------------------------------------
_lib("fakelib_sklearn", {
    "": M(5, 2, imports=("fakelib_sklearn.base",
                         "fakelib_sklearn.linear_model",
                         "fakelib_sklearn.ensemble", "fakelib_sklearn.svm",
                         "fakelib_sklearn.preprocessing",
                         "fakelib_sklearn.metrics"),
          use=("base", "linear_model", "preprocessing"),
          export=("linear_model", "ensemble", "svm", "metrics")),
    "base": M(10, 3),
    "linear_model": M(20, 5),
    "ensemble": M(15, 4),
    "svm": M(12, 4),
    "preprocessing": M(10, 2),
    "metrics": M(8, 2),
})

# -- XML (xmlschema / elementpath analogs; CVE case study) --------------------
_lib("fakelib_elementpath", {
    "": M(5, 2, imports=("fakelib_elementpath.xpath",
                         "fakelib_elementpath.parser"),
          use=("xpath", "parser"), export=("xpath",)),
    "xpath": M(12, 3),
    "parser": M(8, 2),
})

_lib("fakelib_xmlschema", {
    "": M(4, 1, imports=("fakelib_elementpath",
                         "fakelib_xmlschema.validators",
                         "fakelib_xmlschema.schema"),
          use=("fakelib_elementpath", "validators", "schema"),
          export=("validators", "schema")),
    "validators": M(15, 4),
    "schema": M(10, 3),
})

# -- the CVE tool's own package (imports xmlschema on its SBOM path) ----------
_lib("fakelib_cvecore", {
    "": M(3, 1, imports=("fakelib_cvecore.checkers",
                         "fakelib_cvecore.scanner",
                         "fakelib_cvecore.sbom"),
          use=("checkers", "scanner"), export=("checkers", "scanner",
                                               "sbom")),
    "checkers": M(20, 5),
    "scanner": M(15, 4),
    "sbom": M(6, 2, imports=("fakelib_xmlschema",),
              use=("fakelib_xmlschema",)),
})

# -- PDF (pdfminer analog; OCRmyPDF) ------------------------------------------
_lib("fakelib_pdfminer", {
    "": M(4, 1, imports=("fakelib_pdfminer.layout",
                         "fakelib_pdfminer.converter",
                         "fakelib_pdfminer.image", "fakelib_pdfminer.cmap",
                         "fakelib_pdfminer.psparser"),
          use=("layout", "converter", "psparser"),
          export=("layout", "image", "cmap")),
    "layout": M(15, 4),
    "converter": M(12, 3),
    "image": M(10, 3),
    "cmap": M(18, 6),
    "psparser": M(10, 2),
})

# -- forecasting (prophet analog; SensorTD: 1.99x) ----------------------------
_lib("fakelib_prophet", {
    "": M(5, 2, imports=("fakelib_prophet.forecaster",
                         "fakelib_prophet.models", "fakelib_prophet.plot",
                         "fakelib_prophet.diagnostics",
                         "fakelib_prophet.serialize"),
          use=("forecaster", "models"),
          export=("forecaster", "plot", "diagnostics")),
    "forecaster": M(25, 8),
    "models": M(12, 4),
    "plot": M(20, 6),
    "diagnostics": M(15, 4),
    "serialize": M(8, 2),
})

# -- package management (pkg_resources analog; FWB-CML: 1.17x) ----------------
_lib("fakelib_pkgres", {
    "": M(12, 3, imports=("fakelib_pkgres.working_set",
                          "fakelib_pkgres.extern",
                          "fakelib_pkgres._vendor"),
          use=("working_set", "extern"), export=("working_set",)),
    "working_set": M(20, 4),
    "extern": M(8, 2),
    "_vendor": M(7, 3),
})

# -- small fully-used libraries for the "clean" apps --------------------------
_lib("fakelib_mathcore", {
    "": M(3, 1, imports=("fakelib_mathcore.ops",), use=("ops",)),
    "ops": M(6, 1),
})
_lib("fakelib_imgsmall", {
    "": M(3, 1, imports=("fakelib_imgsmall.resize",), use=("resize",)),
    "resize": M(7, 2),
})
_lib("fakelib_jsonlib", {
    "": M(2, 1, imports=("fakelib_jsonlib.codec",), use=("codec",)),
    "codec": M(5, 1),
})
_lib("fakelib_wordlib", {
    "": M(2, 1, imports=("fakelib_wordlib.tokens",), use=("tokens",)),
    "tokens": M(5, 1),
})


# ---------------------------------------------------------------------------
# Applications (paper Table II + 5 clean apps)
# ---------------------------------------------------------------------------

H = HandlerSpec


def _app(name: str, paper_id: str, suite: str, imports: tuple[str, ...],
         handlers: tuple[HandlerSpec, ...], flagged: tuple[str, ...] = (),
         target: float = 1.0) -> AppSpec:
    return AppSpec(name=name, paper_id=paper_id, suite=suite,
                   import_lines=imports, handlers=handlers,
                   expected_flagged=flagged, target_init_speedup=target)


APPS: dict[str, AppSpec] = {}

for spec in [
    # ---------------------------------------------------- RainbowCake
    _app("dna_visualisation", "R-DV", "rainbowcake",
         ("import fakelib_numpy",),
         (H("visualise", 0.96, ("fakelib_numpy.core.work(22)",
                                "fakelib_numpy.linalg.work(5)")),
          H("spectrum", 0.04, ("fakelib_numpy.fft.work(4)",))),
         flagged=("fakelib_numpy.polynomial", "fakelib_numpy.random",
                  "fakelib_numpy.ma", "fakelib_numpy.fft"),
         target=2.30),
    _app("graph_bfs", "R-GB", "rainbowcake",
         ("import fakelib_igraph",),
         (H("bfs", 0.94, ("fakelib_igraph.core.work(20)",)),
          H("stats", 0.03, ("fakelib_igraph.core.work(8)",)),
          H("render", 0.03, ("fakelib_igraph.drawing.matplotlib.work(6)",))),
         flagged=("fakelib_igraph.drawing", "fakelib_igraph.community",
                  "fakelib_igraph.legacy"),
         target=1.71),
    _app("graph_mst", "R-GM", "rainbowcake",
         ("import fakelib_igraph",),
         (H("mst", 0.95, ("fakelib_igraph.core.work(22)",)),
          H("render", 0.05, ("fakelib_igraph.drawing.cairo.work(5)",))),
         flagged=("fakelib_igraph.drawing", "fakelib_igraph.community",
                  "fakelib_igraph.legacy"),
         target=1.74),
    _app("graph_pagerank", "R-GPR", "rainbowcake",
         ("import fakelib_igraph",),
         (H("pagerank", 0.90, ("fakelib_igraph.core.work(18)",
                               "fakelib_igraph.community.work(6)",)),
          H("render", 0.10, ("fakelib_igraph.drawing.matplotlib.work(4)",))),
         flagged=("fakelib_igraph.drawing", "fakelib_igraph.legacy"),
         target=1.70),
    _app("sentiment_analysis_r", "R-SA", "rainbowcake",
         ("import fakelib_nltk", "import fakelib_textblob"),
         (H("analyze", 0.92, ("fakelib_nltk.tokenize.work(14)",
                              "fakelib_textblob.blob.work(6)",
                              "fakelib_textblob.sentiments.work(5)")),
          H("corpus_stats", 0.06, ("fakelib_nltk.corpus.work(6)",
                                   "fakelib_nltk.data.work(4)")),
          H("tag_text", 0.02, ("fakelib_nltk.tag.work(3)",))),
         flagged=("fakelib_nltk.sem", "fakelib_nltk.stem",
                  "fakelib_nltk.parse", "fakelib_nltk.tag"),
         target=1.35),
    # ------------------------------------------------------ FaaSLight
    _app("price_ml_predict", "FL-PMP", "faaslight",
         ("import fakelib_scipy",),
         (H("predict", 0.95, ("fakelib_scipy.optimize.work(18)",
                              "fakelib_scipy.stats.work(8)")),
          H("integrate_curve", 0.05, ("fakelib_scipy.integrate.work(4)",))),
         flagged=("fakelib_scipy.sparse", "fakelib_scipy.signal",
                  "fakelib_scipy.interpolate"),
         target=1.31),
    _app("skimage_numpy", "FL-SN", "faaslight",
         ("import fakelib_skimage", "import fakelib_numpy"),
         (H("filter_image", 0.94, ("fakelib_skimage.filters.work(16)",
                                   "fakelib_numpy.core.work(8)")),
          H("recolor", 0.06, ("fakelib_skimage.color.work(5)",))),
         flagged=("fakelib_skimage.morphology",
                  "fakelib_skimage.segmentation",
                  "fakelib_numpy.random"),
         target=1.41),
    _app("predict_wine_ml", "FL-PWM", "faaslight",
         ("import fakelib_pandas",),
         (H("predict", 0.97, ("fakelib_pandas.core.work(20)",
                              "fakelib_pandas.io.work(6)")),
          H("describe", 0.03, ("fakelib_pandas.computation.work(4)",))),
         flagged=("fakelib_pandas.plotting", "fakelib_pandas.tseries",
                  "fakelib_pandas.window"),
         target=1.76),
    _app("train_wine_ml", "FL-TWM", "faaslight",
         ("import fakelib_pandas",),
         (H("train", 0.96, ("fakelib_pandas.core.work(26)",
                            "fakelib_pandas.io.work(8)")),
          H("profile_data", 0.04, ("fakelib_pandas.computation.work(5)",))),
         flagged=("fakelib_pandas.plotting", "fakelib_pandas.tseries",
                  "fakelib_pandas.window"),
         target=1.79),
    _app("sentiment_analysis_fl", "FL-SA", "faaslight",
         ("import fakelib_pandas", "import fakelib_scipy"),
         (H("analyze", 0.98, ("fakelib_pandas.core.work(16)",
                              "fakelib_scipy.stats.work(10)")),
          H("aggregate", 0.02, ("fakelib_pandas.io.work(4)",))),
         flagged=("fakelib_pandas.plotting", "fakelib_pandas.tseries",
                  "fakelib_scipy.sparse", "fakelib_scipy.signal"),
         target=2.01),
    # -------------------------------------------------- FaaSWorkbench
    _app("chameleon", "FWB-CML", "faasworkbench",
         ("import fakelib_pkgres",),
         (H("render_template", 0.97, ("fakelib_pkgres.working_set.work(18)",)),
          H("list_plugins", 0.03, ("fakelib_pkgres.extern.work(4)",))),
         flagged=("fakelib_pkgres._vendor",),
         target=1.17),
    _app("model_training", "FWB-MT", "faasworkbench",
         ("import fakelib_scipy", "import fakelib_sklearn"),
         (H("train", 0.95, ("fakelib_sklearn.linear_model.work(16)",
                            "fakelib_scipy.optimize.work(10)",
                            "fakelib_sklearn.preprocessing.work(5)")),
          H("score", 0.05, ("fakelib_sklearn.metrics.work(4)",))),
         flagged=("fakelib_sklearn.ensemble", "fakelib_sklearn.svm",
                  "fakelib_scipy.sparse"),
         target=1.21),
    _app("model_serving", "FWB-MS", "faasworkbench",
         ("import fakelib_scipy", "import fakelib_sklearn",
          "import fakelib_numpy"),
         (H("serve", 0.97, ("fakelib_sklearn.linear_model.work(14)",
                            "fakelib_numpy.core.work(8)",
                            "fakelib_scipy.stats.work(6)")),
          H("batch_score", 0.03, ("fakelib_sklearn.metrics.work(4)",))),
         flagged=("fakelib_sklearn.ensemble", "fakelib_sklearn.svm",
                  "fakelib_numpy.random", "fakelib_numpy.fft"),
         target=1.23),
    # ----------------------------------------------------- Real-world
    _app("ocrmypdf", "OCRmyPDF", "realworld",
         ("import fakelib_pdfminer",),
         (H("ocr", 0.94, ("fakelib_pdfminer.layout.work(14)",
                          "fakelib_pdfminer.converter.work(8)",
                          "fakelib_pdfminer.psparser.work(6)")),
          H("extract_images", 0.06, ("fakelib_pdfminer.image.work(5)",))),
         flagged=("fakelib_pdfminer.cmap", "fakelib_pdfminer.image"),
         target=1.42),
    _app("cve_bin_tool", "CVE-bin-tool", "realworld",
         ("import fakelib_cvecore",),
         (H("scan", 0.97, ("fakelib_cvecore.checkers.work(16)",
                           "fakelib_cvecore.scanner.work(10)")),
          H("sbom_scan", 0.03, ("fakelib_cvecore.sbom.work(4)",))),
         flagged=("fakelib_xmlschema", "fakelib_cvecore.sbom"),
         target=1.27),
    _app("sensor_telemetry", "SensorTD", "realworld",
         ("import fakelib_prophet",),
         (H("forecast", 0.96, ("fakelib_prophet.forecaster.work(22)",
                               "fakelib_prophet.models.work(8)")),
          H("backtest", 0.04, ("fakelib_prophet.diagnostics.work(5)",))),
         flagged=("fakelib_prophet.plot", "fakelib_prophet.diagnostics",
                  "fakelib_prophet.serialize"),
         target=1.99),
    _app("heart_failure", "HFP", "realworld",
         ("import fakelib_scipy", "import fakelib_sklearn"),
         (H("predict_risk", 0.96, ("fakelib_sklearn.linear_model.work(14)",
                                   "fakelib_scipy.stats.work(10)")),
          H("cohort_stats", 0.04, ("fakelib_scipy.stats.work(6)",))),
         flagged=("fakelib_scipy.sparse", "fakelib_scipy.signal",
                  "fakelib_sklearn.ensemble", "fakelib_sklearn.svm"),
         target=1.38),
    # ----------------------------------------------------------- clean
    _app("echo", "clean-1", "clean", (),
         (H("echo", 1.0, ("len(str(event)) if event else 0",)),)),
    _app("json_transform", "clean-2", "clean",
         ("import fakelib_jsonlib",),
         (H("transform", 1.0, ("fakelib_jsonlib.codec.work(12)",)),)),
    _app("wordcount", "clean-3", "clean",
         ("import fakelib_wordlib",),
         (H("count", 1.0, ("fakelib_wordlib.tokens.work(12)",)),)),
    _app("matrix_small", "clean-4", "clean",
         ("import fakelib_mathcore",),
         (H("multiply", 1.0, ("fakelib_mathcore.ops.work(14)",)),)),
    _app("thumbnail", "clean-5", "clean",
         ("import fakelib_imgsmall",),
         (H("resize", 1.0, ("fakelib_imgsmall.resize.work(14)",)),)),
]:
    APPS[spec.name] = spec


def lib_closure(libs: tuple[str, ...]) -> list[str]:
    """Transitive fakelib dependencies (textblob -> nltk, etc.)."""
    seen: list[str] = []
    stack = list(libs)
    while stack:
        lib = stack.pop(0)
        if lib in seen or lib not in LIBS:
            continue
        seen.append(lib)
        for mod in LIBS[lib].modules.values():
            for imp in mod.imports:
                root = imp.split(".")[0]
                if root != lib and root.startswith("fakelib_"):
                    stack.append(root)
    return seen


PAPER_TABLE2 = {
    # paper_id -> (init_speedup, e2e_speedup) from Table II, for
    # side-by-side reporting in EXPERIMENTS.md.
    "R-DV": (2.30, 2.26), "R-GB": (1.71, 1.66), "R-GM": (1.74, 1.70),
    "R-GPR": (1.70, 1.62), "R-SA": (1.35, 1.33), "FL-PMP": (1.31, 1.30),
    "FL-SN": (1.41, 1.36), "FL-PWM": (1.76, 1.68), "FL-TWM": (1.79, 1.50),
    "FL-SA": (2.01, 2.01), "FWB-CML": (1.17, 1.05), "FWB-MT": (1.21, 1.09),
    "FWB-MS": (1.23, 1.10), "OCRmyPDF": (1.42, 1.19),
    "CVE-bin-tool": (1.27, 1.20), "SensorTD": (1.99, 1.09),
    "HFP": (1.38, 1.30),
}
