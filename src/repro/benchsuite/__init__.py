"""Synthetic serverless benchmark suite.

The paper evaluates SLIMSTART on 22 Python serverless applications
(RainbowCake / FaaSLight / FaaSWorkbench suites + 4 real-world apps)
whose heavy dependencies (numpy, igraph, nltk, pandas, scipy, …) are not
installed in this offline container.  This package *generates* a
structurally equivalent suite:

* ``specs``    — declarative library + application specs whose import-time
  CPU cost and memory footprint are calibrated to the paper's Table II
  scale factors (unused-init fractions sized to the reported speedups);
* ``genlibs``  — writes the library trees and per-app deployments
  (handler.py + vendored libs, like a Lambda zip);
* ``runner``   — the in-subprocess entry that performs ONE cold start and
  reports init / e2e / peak-RSS metrics (optionally with the SLIMSTART
  profiler attached);
* ``harness``  — spawns fresh subprocesses per cold start, aggregates
  distributions (mean + p99);
* ``pipeline`` — deprecated shims over :mod:`repro.api` (the stage-based
  ``SlimStart`` facade now owns the profile → analyze → optimize →
  re-measure loop and the FaaSLight-style static baseline);
* ``workload`` — skewed and time-varying handler-invocation distributions
  (paper Fig. 3 / Fig. 10).
"""

from repro.benchsuite.specs import APPS, LIBS, AppSpec, LibSpec
from repro.benchsuite.genlibs import build_suite, suite_root
from repro.benchsuite.harness import ColdStartStats, measure_cold_starts
from repro.benchsuite.pipeline import (
    SlimstartPipeline,
    StaticPipeline,
    profile_app,
)

__all__ = [
    "APPS",
    "LIBS",
    "AppSpec",
    "LibSpec",
    "build_suite",
    "suite_root",
    "ColdStartStats",
    "measure_cold_starts",
    "SlimstartPipeline",
    "StaticPipeline",
    "profile_app",
]
