"""Legacy pipeline entry points — thin shims over :mod:`repro.api`.

The SLIMSTART flow (paper Fig. 4) now lives in the stage-based public
API: :class:`repro.api.SlimStart` chains ``ProfileStage → AnalyzeStage
→ OptimizeStage`` (and optionally ``WarmStage`` / ``ReplayStage``) over
one :class:`~repro.api.stages.RunContext`.  This module keeps the seed
repo's names importable:

* the helper functions (``profile_app``, ``analyze_sink``,
  ``apply_defer_targets``) are re-exported from
  :mod:`repro.api.stages` unchanged;
* :class:`SlimstartPipeline` / :class:`StaticPipeline` are deprecated
  wrappers that emit a :class:`DeprecationWarning` and delegate to the
  facade, preserving their old constructor and ``run()`` signatures and
  the :class:`PipelineResult` return shape.

New code should use ``repro.api`` (or ``python -m repro``) directly.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional

# Re-exports for legacy callers; the implementations moved to repro.api.
from repro.api.stages import (  # noqa: F401
    _merge_import_timers,
    analyze_sink,
    apply_defer_targets,
    fresh_variant as _fresh_variant,
    profile_app,
)
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import AnalyzerConfig


@dataclass
class PipelineResult:
    app: str
    variant_dir: str
    report: Optional[OptimizationReport]
    apply_summary: dict


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"{old} is deprecated; use {new} (see repro.api / "
        f"`python -m repro`)", DeprecationWarning, stacklevel=3)


class SlimstartPipeline:
    """Deprecated: use :meth:`repro.api.SlimStart.profile_guided`."""

    def __init__(self, app_name: str, root: str | None = None) -> None:
        _deprecated("SlimstartPipeline", "SlimStart.profile_guided")
        from repro.api import SlimStart
        from repro.api.stages import RunContext
        self._facade_cls = SlimStart
        self.app = app_name
        ctx = RunContext.for_app(app_name, root)
        self.root = self._root = ctx.root
        self.app_dir = ctx.app_dir
        self.sink = ctx.sink
        self.variant_dir = ctx.variant_dir
        self.report_path = ctx.report_path

    def run(self, *, instances: int = 4, invocations: int = 150,
            config: AnalyzerConfig | None = None) -> PipelineResult:
        facade = self._facade_cls.profile_guided(
            self.app, self._root, instances=instances,
            invocations=invocations, config=config)
        # honor path overrides callers made on the old attributes
        facade.ctx.app_dir = self.app_dir
        facade.ctx.sink = self.sink
        facade.ctx.report_path = self.report_path
        facade.ctx.variant_dir = self.variant_dir
        ctx = facade.run()
        return PipelineResult(ctx.app, ctx.variant_dir, ctx.report,
                              ctx.apply_summary)


class StaticPipeline:
    """Deprecated: use :meth:`repro.api.SlimStart.static_baseline`."""

    def __init__(self, app_name: str, root: str | None = None) -> None:
        _deprecated("StaticPipeline", "SlimStart.static_baseline")
        from repro.api import SlimStart
        from repro.api.stages import RunContext
        self._facade_cls = SlimStart
        self.app = app_name
        ctx = RunContext.for_app(app_name, root, variant="static")
        self.root = self._root = ctx.root
        self.app_dir = ctx.app_dir
        self.variant_dir = ctx.variant_dir

    def run(self) -> PipelineResult:
        facade = self._facade_cls.static_baseline(self.app, self._root)
        # honor path overrides callers made on the old attributes
        facade.ctx.app_dir = self.app_dir
        facade.ctx.variant_dir = self.variant_dir
        ctx = facade.run()
        return PipelineResult(ctx.app, ctx.variant_dir, None,
                              ctx.apply_summary)
