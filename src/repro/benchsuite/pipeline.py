"""End-to-end SLIMSTART and FaaSLight-baseline pipelines.

SLIMSTART flow (paper Fig. 4):

    deploy (baseline apps/<app>)                 # cold-start measurable
      -> profile: N instances x M invocations    # runner --profile
      -> analyze: merge shards, U(L), findings   # UtilizationAnalyzer
      -> optimize: AST deferred imports          # variants/<app>/slimstart
      -> re-measure

Static (FaaSLight-style) flow: same deploy + same AST actuator, but the
defer targets come from static reachability instead of runtime profiles,
so workload-dependent libraries survive (paper Observation 2).
"""

from __future__ import annotations

import json
import os
import shutil
import statistics
from dataclasses import dataclass
from typing import Optional

from repro.benchsuite.genlibs import build_suite, suite_root
from repro.benchsuite.harness import run_instance
from repro.core.optimizer.ast_transform import optimize_file
from repro.core.optimizer.static_baseline import StaticReachability
from repro.core.profiler.cct import CCT
from repro.core.profiler.collector import read_shards
from repro.core.profiler.import_timer import ImportTimer
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import (
    AnalyzerConfig,
    ModuleMapper,
    UtilizationAnalyzer,
)


# ---------------------------------------------------------------------------
# Profiling + analysis
# ---------------------------------------------------------------------------

def profile_app(app_dir: str, sink: str, *, instances: int = 4,
                invocations: int = 150, seed0: int = 1000,
                sample_interval: float = 0.002) -> None:
    """Run ``instances`` profiled cold instances (sample aggregation
    across invocations, paper TC-1 strategy 2)."""
    os.makedirs(sink, exist_ok=True)
    for i in range(instances):
        run_instance(app_dir, invocations=invocations, seed=seed0 + i,
                     profile=True, sink=sink,
                     sample_interval=sample_interval)


def _merge_import_timers(dicts: list[dict]) -> ImportTimer:
    """Mean-merge per-module init times across instances."""
    sums: dict[str, dict] = {}
    counts: dict[str, int] = {}
    for d in dicts:
        for name, rec in d.items():
            if name not in sums:
                sums[name] = dict(rec)
                counts[name] = 1
            else:
                sums[name]["self_s"] += rec["self_s"]
                sums[name]["cumulative_s"] += rec["cumulative_s"]
                counts[name] += 1
    for name, rec in sums.items():
        rec["self_s"] /= counts[name]
        rec["cumulative_s"] /= counts[name]
    return ImportTimer.from_dict(sums)


def analyze_sink(app_name: str, sink: str, libs_dir: str,
                 config: AnalyzerConfig | None = None) -> OptimizationReport:
    """Merge profile shards and produce the optimization report."""
    records = [r for r in read_shards(sink) if r.get("app")]
    if not records:
        raise RuntimeError(f"no profile shards in {sink}")
    timer = _merge_import_timers([r["init_records"] for r in records])
    cct = CCT()
    for r in records:
        cct.merge(CCT.from_dict(r["cct"]))
    cct.escalate()
    e2e = statistics.fmean(r["e2e_cold_s"] for r in records)
    mapper = ModuleMapper((libs_dir,))
    analyzer = UtilizationAnalyzer(timer, cct, mapper, e2e_s=e2e,
                                   config=config)
    return OptimizationReport.from_analyzer(app_name, analyzer)


# ---------------------------------------------------------------------------
# Applying optimizations to a deployment copy
# ---------------------------------------------------------------------------

def _deployment_py_files(deploy_dir: str):
    libs_dir = os.path.join(deploy_dir, "libs")
    yield os.path.join(deploy_dir, "handler.py"), "handler", False
    for dirpath, _dirs, files in os.walk(libs_dir):
        for fn in files:
            if not fn.endswith(".py") or fn.endswith(".orig"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, libs_dir)[:-3]
            parts = rel.split(os.sep)
            is_pkg = parts[-1] == "__init__"
            if is_pkg:
                parts = parts[:-1]
            yield path, ".".join(parts), is_pkg


def apply_defer_targets(deploy_dir: str,
                        targets_by_module: dict[str, list[str]] | None = None,
                        global_targets: list[str] | None = None) -> dict:
    """Rewrite a deployment in place.

    ``global_targets`` (SLIMSTART): every file is rewritten against the
    full target list.  ``targets_by_module`` (static baseline): each
    module only defers its own provably-dead imports.
    """
    summary = {"files_changed": 0, "deferred": 0, "skipped": 0}
    for path, module_name, is_pkg in _deployment_py_files(deploy_dir):
        if global_targets is not None:
            targets = global_targets
        else:
            targets = (targets_by_module or {}).get(module_name, [])
        if not targets:
            continue
        res = optimize_file(path, targets, module_name=module_name)
        if res.changed:
            summary["files_changed"] += 1
        summary["deferred"] += len(res.deferred)
        summary["skipped"] += len(res.skipped)
    return summary


def _fresh_variant(base_dir: str, variant_dir: str) -> str:
    if os.path.isdir(variant_dir):
        shutil.rmtree(variant_dir)
    os.makedirs(os.path.dirname(variant_dir), exist_ok=True)
    shutil.copytree(base_dir, variant_dir)
    return variant_dir


# ---------------------------------------------------------------------------
# Pipelines
# ---------------------------------------------------------------------------

@dataclass
class PipelineResult:
    app: str
    variant_dir: str
    report: Optional[OptimizationReport]
    apply_summary: dict


class SlimstartPipeline:
    """Profile-guided (dynamic) pipeline — the paper's tool."""

    def __init__(self, app_name: str, root: str | None = None) -> None:
        self.app = app_name
        self.root = root or build_suite()
        self.app_dir = os.path.join(self.root, "apps", app_name)
        self.sink = os.path.join(self.root, "profiles", app_name)
        self.variant_dir = os.path.join(self.root, "variants", app_name,
                                        "slimstart")
        self.report_path = os.path.join(self.root, "reports",
                                        f"{app_name}.json")

    def run(self, *, instances: int = 4, invocations: int = 150,
            config: AnalyzerConfig | None = None) -> PipelineResult:
        if os.path.isdir(self.sink):
            shutil.rmtree(self.sink)
        profile_app(self.app_dir, self.sink, instances=instances,
                    invocations=invocations)
        libs_dir = os.path.join(self.app_dir, "libs")
        report = analyze_sink(self.app, self.sink, libs_dir, config=config)
        report.save(self.report_path)
        _fresh_variant(self.app_dir, self.variant_dir)
        summary = apply_defer_targets(self.variant_dir,
                                      global_targets=report.defer_targets)
        return PipelineResult(self.app, self.variant_dir, report, summary)


class StaticPipeline:
    """FaaSLight-style static baseline (paper §II-B comparison)."""

    def __init__(self, app_name: str, root: str | None = None) -> None:
        self.app = app_name
        self.root = root or build_suite()
        self.app_dir = os.path.join(self.root, "apps", app_name)
        self.variant_dir = os.path.join(self.root, "variants", app_name,
                                        "static")

    def run(self) -> PipelineResult:
        libs_dir = os.path.join(self.app_dir, "libs")
        static = StaticReachability([libs_dir])
        static.add_module(os.path.join(self.app_dir, "handler.py"),
                          "handler")
        targets_by_module = static.unreachable_imports("handler")
        _fresh_variant(self.app_dir, self.variant_dir)
        summary = apply_defer_targets(self.variant_dir,
                                      targets_by_module=targets_by_module)
        return PipelineResult(self.app, self.variant_dir, None, summary)
