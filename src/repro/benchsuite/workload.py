"""Workload models (paper Fig. 3 and Fig. 10).

* ``skewed_weights`` — Zipf-like handler distributions: the paper's
  production-trace study found 54 % of functions have >1 entry point and
  the top few handlers take >80 % of invocations.
* ``ShiftingWorkload`` — a piecewise-stationary trace generator used by
  the adaptive-profiling benchmark: long stable phases with occasional
  distribution shifts (the paper observes peaks at ~144 h / ~228 h in
  production traces).
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Iterator


def skewed_weights(handlers: list[str], s: float = 1.6,
                   rng: random.Random | None = None) -> dict[str, float]:
    """Zipf(s) weights over handlers (first handler hottest)."""
    w = [1.0 / (i + 1) ** s for i in range(len(handlers))]
    total = sum(w)
    return {h: wi / total for h, wi in zip(handlers, w)}


@dataclass
class Phase:
    duration_s: float
    weights: dict[str, float]


@dataclass
class ShiftingWorkload:
    """Piecewise-stationary invocation trace."""

    phases: list[Phase]
    rate_per_s: float = 10.0
    seed: int = 0

    def events(self) -> Iterator[tuple[float, str]]:
        """Yield (timestamp, handler) events across all phases."""
        rng = random.Random(self.seed)
        t = 0.0
        for phase in self.phases:
            names = list(phase.weights)
            probs = [phase.weights[n] for n in names]
            end = t + phase.duration_s
            while t < end:
                t += rng.expovariate(self.rate_per_s)
                if t >= end:
                    break
                yield t, rng.choices(names, weights=probs, k=1)[0]

    @classmethod
    def stable_then_shift(cls, handlers: list[str], window_s: float,
                          n_stable: int = 6, n_shifted: int = 4,
                          rate_per_s: float = 10.0,
                          seed: int = 0) -> "ShiftingWorkload":
        """A long stable phase followed by a flipped distribution —
        the canonical trigger scenario for Eq. 7."""
        base = skewed_weights(handlers)
        flipped = skewed_weights(list(reversed(handlers)))
        return cls(
            phases=[
                Phase(duration_s=n_stable * window_s, weights=base),
                Phase(duration_s=n_shifted * window_s, weights=flipped),
            ],
            rate_per_s=rate_per_s,
            seed=seed,
        )
