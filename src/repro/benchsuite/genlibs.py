"""Generator: writes the synthetic library trees and per-app deployments.

Layout under the suite root (default ``<repo>/.benchsuite``)::

    libs_src/<lib>/...            master copies of the fake libraries
    apps/<app>/handler.py         the application entry module
    apps/<app>/meta.json          handlers, weights, paper id, ...
    apps/<app>/libs/<lib>/...     vendored per-app library copies
                                  (a Lambda-zip analog; optimization
                                  mutates per-app copies only)

Modules burn real CPU at import time (a calibrated busy loop) and hold
page-touched ballast, so initialization latency and peak RSS measured by
the harness are genuine, not simulated numbers.
"""

from __future__ import annotations

import json
import os
import shutil

from repro.benchsuite.specs import APPS, LIBS, AppSpec, LibSpec, ModSpec, lib_closure

DEFAULT_ROOT = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))))), ".benchsuite")


def suite_root() -> str:
    return os.environ.get("SLIMSTART_SUITE", DEFAULT_ROOT)


# ---------------------------------------------------------------------------
# Module rendering
# ---------------------------------------------------------------------------

_MODULE_HEADER = '''\
"""Auto-generated module {dotted} (SLIMSTART benchsuite; not a real library)."""
import time as _time

# -- calibrated import-time cost ------------------------------------------
_end = _time.perf_counter() + {spin_ms} / 1000.0
while _time.perf_counter() < _end:
    pass
_BALLAST = bytearray(int({alloc_mb} * 1048576)) or bytearray(1)
_BALLAST[::4096] = b"\\x01" * len(_BALLAST[::4096])
'''

_MODULE_BODY = '''

def work(ms):
    """Busy loop attributed to this module by the sampling profiler."""
    end = _time.perf_counter() + ms / 1000.0
    x = 0
    while _time.perf_counter() < end:
        x += 1
    return x


def compute(n):
    s = 0
    for i in range(int(n)):
        s += (i * i) % 97
    return s
'''

_TOUCH_FN = '''

def _touch_static():
    """References kept so static reachability must retain these imports."""
    return ({names})
'''


def _import_line(target: str) -> str:
    if "." in target:
        parent, child = target.rsplit(".", 1)
        return f"from {parent} import {child}"
    return f"import {target}"


def render_module(dotted: str, spec: ModSpec) -> str:
    src = _MODULE_HEADER.format(dotted=dotted, spin_ms=spec.spin_ms,
                                alloc_mb=spec.alloc_mb)
    if spec.imports:
        src += "\n" + "\n".join(_import_line(t) for t in spec.imports) + "\n"
    if spec.export:
        src += f"\n__all__ = {list(spec.export)!r}\n"
    src += _MODULE_BODY
    if spec.use:
        src += _TOUCH_FN.format(names=", ".join(spec.use) + ("," if len(spec.use) == 1 else ""))
    return src


def write_lib(lib: LibSpec, dest: str) -> None:
    """Write one library tree under ``dest`` (its parent dir)."""
    for suffix, spec in lib.modules.items():
        dotted = lib.name if not suffix else f"{lib.name}.{suffix}"
        rel = dotted.replace(".", os.sep)
        # A name is a package iff any other module nests under it.
        is_pkg = any(
            other != suffix and (other.startswith(suffix + ".") if suffix
                                 else True)
            for other in lib.modules
        )
        if is_pkg:
            path = os.path.join(dest, rel, "__init__.py")
        else:
            path = os.path.join(dest, rel + ".py")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as fh:
            fh.write(render_module(dotted, spec))


# ---------------------------------------------------------------------------
# Application rendering
# ---------------------------------------------------------------------------

_APP_TEMPLATE = '''\
"""Auto-generated serverless application {name} ({paper_id})."""
{imports}

{handler_defs}

HANDLERS = {{{handler_map}}}
WEIGHTS = {{{weight_map}}}


def handler(event=None):
    """Default Lambda-style entry point: dispatch on event["op"]."""
    op = (event or {{}}).get("op") or "{hot}"
    return HANDLERS[op](event)
'''

_HANDLER_TEMPLATE = '''\
def {name}(event=None):
    _out = 0
{body}
    return {{"handler": "{name}", "ok": True, "out": _out}}
'''


def render_app(app: AppSpec) -> str:
    handler_defs = []
    for h in app.handlers:
        body = "\n".join(f"    _out += {line}" if not line.startswith("_")
                         else f"    {line}" for line in h.body)
        handler_defs.append(_HANDLER_TEMPLATE.format(name=h.name, body=body))
    return _APP_TEMPLATE.format(
        name=app.name,
        paper_id=app.paper_id,
        imports="\n".join(app.import_lines),
        handler_defs="\n\n".join(handler_defs),
        handler_map=", ".join(f'"{h.name}": {h.name}' for h in app.handlers),
        weight_map=", ".join(f'"{h.name}": {h.weight}' for h in app.handlers),
        hot=app.hot_handler,
    )


# ---------------------------------------------------------------------------
# Suite build
# ---------------------------------------------------------------------------

def build_app(app: AppSpec, root: str) -> str:
    """Write one app deployment (handler + vendored libs). Returns its dir."""
    app_dir = os.path.join(root, "apps", app.name)
    libs_src = os.path.join(root, "libs_src")
    if os.path.isdir(app_dir):
        shutil.rmtree(app_dir)
    libs_dir = os.path.join(app_dir, "libs")
    os.makedirs(libs_dir, exist_ok=True)
    with open(os.path.join(app_dir, "handler.py"), "w") as fh:
        fh.write(render_app(app))
    for lib in lib_closure(app.libs):
        shutil.copytree(os.path.join(libs_src, lib),
                        os.path.join(libs_dir, lib))
    meta = {
        "name": app.name,
        "paper_id": app.paper_id,
        "suite": app.suite,
        "handlers": {h.name: h.weight for h in app.handlers},
        "hot_handler": app.hot_handler,
        "libs": lib_closure(app.libs),
        "expected_flagged": list(app.expected_flagged),
        "target_init_speedup": app.target_init_speedup,
    }
    with open(os.path.join(app_dir, "meta.json"), "w") as fh:
        json.dump(meta, fh, indent=2)
    return app_dir


def build_suite(root: str | None = None, force: bool = False,
                apps: list[str] | None = None) -> str:
    """Generate the whole suite. Idempotent unless ``force``."""
    root = root or suite_root()
    manifest_path = os.path.join(root, "manifest.json")
    if os.path.exists(manifest_path) and not force:
        return root
    libs_src = os.path.join(root, "libs_src")
    if os.path.isdir(libs_src):
        shutil.rmtree(libs_src)
    os.makedirs(libs_src, exist_ok=True)
    for lib in LIBS.values():
        write_lib(lib, libs_src)
    selected = apps or list(APPS)
    for name in selected:
        build_app(APPS[name], root)
    with open(manifest_path, "w") as fh:
        json.dump({
            "apps": selected,
            "libs": sorted(LIBS),
            "lib_init_ms": {k: v.total_init_ms() for k, v in LIBS.items()},
        }, fh, indent=2)
    return root


if __name__ == "__main__":
    import sys
    print(build_suite(force="--force" in sys.argv))
