"""In-subprocess cold-start runner.

One execution of this module == one serverless *instance lifecycle*:

    fresh CPython process (cold)  ->  import handler module (init)
    ->  N handler invocations (possibly spanning several requests,
        like a warm container)    ->  metrics JSON on stdout

With ``--profile`` the SLIMSTART profiler is attached exactly as it
would be in production (paper §IV-D): the import timer hooks
``sys.meta_path`` before the handler import, the sampling profiler runs
across init + invocations, and one instance-record is batch-written to
the sink directory through the AsyncCollector.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import random
import resource
import sys
import time


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app-dir", required=True)
    ap.add_argument("--invocations", type=int, default=1)
    ap.add_argument("--handler", default=None,
                    help="force a single handler (default: sample WEIGHTS)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile", action="store_true")
    ap.add_argument("--sink", default=None, help="profile sink directory")
    ap.add_argument("--sample-interval", type=float, default=0.002)
    args = ap.parse_args(argv)

    app_dir = os.path.abspath(args.app_dir)
    libs_dir = os.path.join(app_dir, "libs")
    sys.path.insert(0, libs_dir)
    sys.path.insert(0, app_dir)

    timer = sampler = None
    if args.profile:
        from repro.core.profiler.import_timer import ImportTimer
        from repro.core.profiler.sampler import CallPathSampler, SamplerConfig
        timer = ImportTimer(only_under=(libs_dir,))
        timer.install()
        sampler = CallPathSampler(
            SamplerConfig(interval_s=args.sample_interval, timer="prof"))
        sampler.start()

    # ---------------------------------------------------------- cold start
    t0 = time.perf_counter()
    handler_mod = importlib.import_module("handler")
    init_s = time.perf_counter() - t0
    if timer is not None:
        timer.uninstall()

    # --------------------------------------------------------- invocations
    weights: dict[str, float] = getattr(handler_mod, "WEIGHTS", {})
    rng = random.Random(args.seed)
    names = list(weights) or ["handler"]
    probs = [weights.get(n, 1.0) for n in names]

    def pick() -> str:
        if args.handler:
            return args.handler
        return rng.choices(names, weights=probs, k=1)[0]

    invocation_s: list[tuple[str, float]] = []
    counts: dict[str, int] = {}
    for _ in range(max(1, args.invocations)):
        op = pick()
        ev = {"op": op}
        t1 = time.perf_counter()
        handler_mod.handler(ev)
        invocation_s.append((op, time.perf_counter() - t1))
        counts[op] = counts.get(op, 0) + 1
    e2e_cold_s = init_s + invocation_s[0][1]

    if sampler is not None:
        sampler.stop()

    # NOTE: ru_maxrss is NOT reset by execve, so a child forked from a
    # large parent (e.g. pytest) inherits the parent's peak and floors
    # the measurement.  /proc/self/status VmHWM is per-mm and resets on
    # exec — the faithful "peak memory of this cold instance" number.
    peak_rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    try:
        with open("/proc/self/status") as fh:
            for line in fh:
                if line.startswith("VmHWM:"):
                    peak_rss_kb = int(line.split()[1])
                    break
    except OSError:
        pass

    # ----------------------------------------------------------- profiling
    if args.profile and args.sink:
        from repro.core.profiler.collector import AsyncCollector
        cct = sampler.build_cct()
        record = {
            "app": os.path.basename(app_dir.rstrip("/")),
            "init_s": init_s,
            "e2e_cold_s": e2e_cold_s,
            "init_records": timer.to_dict(),
            "cct": cct.to_dict(),
            "counts": counts,
            "n_signals": sampler.n_signals,
        }
        with AsyncCollector(args.sink, batch_size=4) as col:
            col.put(record)

    per_handler: dict[str, list[float]] = {}
    for op, dt in invocation_s:
        per_handler.setdefault(op, []).append(dt)
    print(json.dumps({
        "init_ms": init_s * 1e3,
        "first_invoke_ms": invocation_s[0][1] * 1e3,
        "e2e_cold_ms": e2e_cold_s * 1e3,
        "mean_invoke_ms": 1e3 * sum(d for _, d in invocation_s)
        / len(invocation_s),
        "peak_rss_kb": peak_rss_kb,
        "invocations": counts,
        "per_handler_ms": {k: 1e3 * sum(v) / len(v)
                           for k, v in per_handler.items()},
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
