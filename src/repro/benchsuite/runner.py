"""In-subprocess cold-start runner.

One execution of this module == one serverless *instance lifecycle*:

    fresh CPython process (cold)  ->  import handler module (init)
    ->  N handler invocations (possibly spanning several requests,
        like a warm container)    ->  metrics JSON on stdout

With ``--profile`` the SLIMSTART profiler is attached exactly as it
would be in production (paper §IV-D): the import timer hooks
``sys.meta_path`` before the handler import, the sampling profiler runs
across init + invocations, and one instance-record is batch-written to
the sink directory through the AsyncCollector.

With ``--preimport mod1,mod2`` the listed modules are imported *before*
the timed handler import — the in-process analog of a pre-warmed zygote
(see ``repro.pool.forkserver``): the timed init then only covers the
handler module plus whatever the hot set did not already pull in.

The invocation loop and RSS measurement are exposed as module-level
helpers (``run_invocations``, ``instance_rss_kb``, ``metrics_dict``) so
the fork-server's forked children report metrics through the exact same
code path as fresh-process cold starts.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import random
import resource
import sys
import threading
import time
from typing import Union


def instance_rss_kb() -> int:
    """Best-available *per-instance* resident-set size in kB.

    Preference order:

    1. ``VmHWM`` from ``/proc/self/status`` — the per-mm high-water mark,
       reset on execve: the faithful "peak memory of this cold instance".
    2. ``VmRSS`` — current RSS.  Some kernels (notably gVisor-style
       sandboxes) expose no VmHWM line; the benchsuite apps hold their
       import-time ballast for the life of the instance, so end-of-run
       VmRSS is an accurate stand-in for the peak.
    3. ``ru_maxrss`` — last resort only: it is NOT reset by execve, so a
       child spawned from a large parent (e.g. pytest) inherits the
       parent's peak and floors the measurement at the parent's RSS.
    """
    hwm = rss = None
    try:
        with open("/proc/self/status") as fh:
            for line in fh:
                if line.startswith("VmHWM:"):
                    hwm = int(line.split()[1])
                elif line.startswith("VmRSS:"):
                    rss = int(line.split()[1])
    except (OSError, ValueError, IndexError):
        pass
    if hwm is not None:
        return hwm
    if rss is not None:
        return rss
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss


_PAGE_KB = os.sysconf("SC_PAGE_SIZE") // 1024 if hasattr(os, "sysconf") \
    else 4


def _statm_kb(pid: Union[int, str] = "self") -> tuple[int, int]:
    """``(resident_kb, shared_kb)`` from ``/proc/<pid>/statm``.

    One line, two integer fields, one multiply — far cheaper than
    scanning the ~50-line ``status`` file, which matters for the
    20 ms-period :class:`PeakRssSampler` and for fleet budget arbiters
    polling every zygote per admission decision.  ``(0, 0)`` when the
    pid is gone or ``/proc`` is unreadable.
    """
    try:
        with open(f"/proc/{pid}/statm") as fh:
            parts = fh.read().split()
        return int(parts[1]) * _PAGE_KB, int(parts[2]) * _PAGE_KB
    except (OSError, ValueError, IndexError):
        return 0, 0


def proc_memory_kb(pid: Union[int, str] = "self") -> dict:
    """Shared/private-aware memory of one process, in kB.

    Prefers ``/proc/<pid>/smaps_rollup`` (``Pss`` plus the
    ``Shared_*``/``Private_*`` rollups — the faithful split for
    CoW-forked zygote trees).  Kernels without it (gVisor-style
    sandboxes, pre-4.14) fall back to ``statm``, whose ``shared``
    column counts only file-backed resident pages, so anonymous CoW
    pages land in ``private_kb`` there; ``pss_kb`` is 0 when unknown.
    Returns ``{"rss_kb", "pss_kb", "shared_kb", "private_kb"}`` (all 0
    for a dead pid).
    """
    try:
        rollup: dict[str, int] = {}
        with open(f"/proc/{pid}/smaps_rollup") as fh:
            for line in fh:
                key, _, rest = line.partition(":")
                if key in ("Rss", "Pss", "Shared_Clean", "Shared_Dirty",
                           "Private_Clean", "Private_Dirty"):
                    rollup[key] = int(rest.split()[0])
        if "Rss" in rollup:
            shared = rollup.get("Shared_Clean", 0) \
                + rollup.get("Shared_Dirty", 0)
            private = rollup.get("Private_Clean", 0) \
                + rollup.get("Private_Dirty", 0)
            return {"rss_kb": rollup["Rss"],
                    "pss_kb": rollup.get("Pss", 0),
                    "shared_kb": shared, "private_kb": private}
    except (OSError, ValueError, IndexError):
        pass
    resident, shared = _statm_kb(pid)
    return {"rss_kb": resident, "pss_kb": 0, "shared_kb": shared,
            "private_kb": max(resident - shared, 0)}


def current_rss_kb() -> int:
    """Current resident set in kB (no high-water mark): the quantity a
    periodic sampler must watch on kernels whose ``/proc`` lacks
    ``VmHWM``.  Reads ``statm`` (single line) rather than re-scanning
    ``status`` — this runs every 20 ms inside live instances."""
    resident, _ = _statm_kb()
    if resident:
        return resident
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss


class PeakRssSampler:
    """Background thread tracking peak RSS by periodic sampling.

    ``VmHWM`` already records the true peak on mainline kernels, but
    sandboxed kernels (gVisor-style) expose only current ``VmRSS`` —
    there, a workload that frees its ballast before exit would report
    the *post-free* RSS as its "peak".  Sampling every ``interval_s``
    while the instance runs recovers a true high-water mark (to sampling
    resolution) on any kernel.  Use as a context manager or
    ``start()``/``stop()``; ``peak_kb`` is valid during and after.
    """

    def __init__(self, interval_s: float = 0.02,
                 read_kb=current_rss_kb) -> None:
        self.interval_s = interval_s
        self._read_kb = read_kb
        self.peak_kb = 0
        self.samples = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _sample(self) -> None:
        self.peak_kb = max(self.peak_kb, self._read_kb())
        self.samples += 1

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self._sample()

    def start(self) -> "PeakRssSampler":
        if self._thread is None:
            self._sample()
            self._stop.clear()
            self._thread = threading.Thread(target=self._loop,
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self) -> int:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=5.0)
            self._thread = None
        self._sample()
        return self.peak_kb

    def __enter__(self) -> "PeakRssSampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def setup_app_path(app_dir: str) -> str:
    """Put ``app_dir`` and its vendored ``libs/`` on ``sys.path``;
    returns the libs dir."""
    app_dir = os.path.abspath(app_dir)
    libs_dir = os.path.join(app_dir, "libs")
    sys.path.insert(0, libs_dir)
    sys.path.insert(0, app_dir)
    return libs_dir


def run_invocations(handler_mod, *, invocations: int = 1,
                    handler: str | None = None, seed: int = 0,
                    ) -> tuple[list[tuple[str, float]], dict[str, int]]:
    """Invoke the handler module like a warm container serving requests.

    Samples entry points from the module's ``WEIGHTS`` (or uses the
    forced ``handler``); returns per-invocation ``(op, seconds)`` pairs
    and per-op counts.
    """
    weights: dict[str, float] = getattr(handler_mod, "WEIGHTS", {})
    rng = random.Random(seed)
    names = list(weights) or ["handler"]
    probs = [weights.get(n, 1.0) for n in names]

    def pick() -> str:
        if handler:
            return handler
        return rng.choices(names, weights=probs, k=1)[0]

    invocation_s: list[tuple[str, float]] = []
    counts: dict[str, int] = {}
    for _ in range(max(1, invocations)):
        op = pick()
        ev = {"op": op}
        t1 = time.perf_counter()
        handler_mod.handler(ev)
        invocation_s.append((op, time.perf_counter() - t1))
        counts[op] = counts.get(op, 0) + 1
    return invocation_s, counts


def metrics_dict(init_s: float, invocation_s: list[tuple[str, float]],
                 counts: dict[str, int], peak_rss_kb: int) -> dict:
    """The runner's stdout JSON payload (shared with fork-pool children)."""
    per_handler: dict[str, list[float]] = {}
    for op, dt in invocation_s:
        per_handler.setdefault(op, []).append(dt)
    e2e_cold_s = init_s + invocation_s[0][1]
    return {
        "init_ms": init_s * 1e3,
        "first_invoke_ms": invocation_s[0][1] * 1e3,
        "e2e_cold_ms": e2e_cold_s * 1e3,
        "mean_invoke_ms": 1e3 * sum(d for _, d in invocation_s)
        / len(invocation_s),
        "peak_rss_kb": peak_rss_kb,
        "invocations": counts,
        "per_handler_ms": {k: 1e3 * sum(v) / len(v)
                           for k, v in per_handler.items()},
    }


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--app-dir", required=True)
    ap.add_argument("--invocations", type=int, default=1)
    ap.add_argument("--handler", default=None,
                    help="force a single handler (default: sample WEIGHTS)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--profile", action="store_true")
    ap.add_argument("--sink", default=None, help="profile sink directory")
    ap.add_argument("--sample-interval", type=float, default=0.002)
    ap.add_argument("--preimport", default=None,
                    help="comma-separated modules imported before the "
                         "timed handler import (pre-warmed hot set)")
    ap.add_argument("--rss-sample-interval", type=float, default=0.02,
                    help="periodic RSS sampling period in seconds "
                         "(0 disables the sampler)")
    args = ap.parse_args(argv)

    app_dir = os.path.abspath(args.app_dir)
    libs_dir = setup_app_path(app_dir)

    if args.preimport:
        for mod in args.preimport.split(","):
            mod = mod.strip()
            if mod:
                importlib.import_module(mod)

    timer = sampler = None
    if args.profile:
        from repro.core.profiler.import_timer import ImportTimer
        from repro.core.profiler.sampler import CallPathSampler, SamplerConfig
        timer = ImportTimer(only_under=(libs_dir,))
        timer.install()
        sampler = CallPathSampler(
            SamplerConfig(interval_s=args.sample_interval, timer="prof"))
        sampler.start()

    # a workload can free its ballast before exit, so end-of-run VmRSS
    # (the VmHWM-less fallback) would under-report the peak — sample
    # RSS periodically across init + invocations for a true high-water
    rss_sampler = None
    if args.rss_sample_interval > 0:
        rss_sampler = PeakRssSampler(args.rss_sample_interval).start()

    # ---------------------------------------------------------- cold start
    t0 = time.perf_counter()
    handler_mod = importlib.import_module("handler")
    init_s = time.perf_counter() - t0
    if timer is not None:
        timer.uninstall()
    rss_after_init = instance_rss_kb()

    # --------------------------------------------------------- invocations
    invocation_s, counts = run_invocations(
        handler_mod, invocations=args.invocations, handler=args.handler,
        seed=args.seed)
    e2e_cold_s = init_s + invocation_s[0][1]

    if sampler is not None:
        sampler.stop()

    peak_rss_kb = max(rss_after_init, instance_rss_kb())
    if rss_sampler is not None:
        peak_rss_kb = max(peak_rss_kb, rss_sampler.stop())

    # ----------------------------------------------------------- profiling
    if args.profile and args.sink:
        from repro.core.profiler.collector import AsyncCollector
        cct = sampler.build_cct()
        record = {
            "app": os.path.basename(app_dir.rstrip("/")),
            "init_s": init_s,
            "e2e_cold_s": e2e_cold_s,
            "init_records": timer.to_dict(),
            "cct": cct.to_dict(),
            "counts": counts,
            "n_signals": sampler.n_signals,
        }
        with AsyncCollector(args.sink, batch_size=4) as col:
            col.put(record)

    print(json.dumps(metrics_dict(init_s, invocation_s, counts,
                                  peak_rss_kb)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
