"""``python -m repro`` — the SLIMSTART workflow as one CLI.

The paper's pitch is CI/CD integration: one command a pipeline job can
run per workload.  Every subcommand is a thin shell over
:mod:`repro.api` (stages + versioned artifacts), so the CLI, the
benchmarks and library callers share exactly one implementation:

    profile APP        profile + analyze → versioned report artifact
    report PATH        render a saved report artifact (Tables IV/V)
    optimize APP       AST deferred-import rewrite → variant deployment
    restore TARGET     undo an optimization from the .orig backups
    pool serve         boot a profile-guided zygote, serve fork starts
    fleet replay       replay a trace through the simulated fleet
    ci-check APP       re-profile; exit 1 if the defer set diverged
                       from the deployed report (the paper's CI gate)

Exit codes: 0 ok / check passed, 1 ci-check divergence, 2 usage or
artifact errors (bad/missing files, schema violations).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional, Sequence

from repro.api import (
    AnalyzeStage,
    ArtifactError,
    OptimizeStage,
    ProfileStage,
    ReplayStage,
    ReportArtifact,
    SlimStart,
    load_report,
    load_trace,
    restore_deployment,
)
from repro.api.render import table
from repro.benchsuite.genlibs import build_suite
from repro.core.profiler.report import render_report


def _resolve_root(args: argparse.Namespace) -> str:
    """--root as given, else the (lazily generated) benchsuite root."""
    return args.root or build_suite()


def _print_rows(rows: Sequence[dict], cols: Sequence[str]) -> None:
    if rows:
        print(table(rows, list(cols)))


# ---------------------------------------------------------------------------
# Subcommands
# ---------------------------------------------------------------------------

def cmd_profile(args: argparse.Namespace) -> int:
    root = _resolve_root(args)
    facade = SlimStart(args.app, root, stages=[
        ProfileStage(instances=args.instances,
                     invocations=args.invocations),
        AnalyzeStage(),
    ])
    if args.out:
        facade.ctx.report_path = os.path.abspath(args.out)
    ctx = facade.run()
    if args.json:
        print(json.dumps(ctx.results["analyze"], indent=2))
    else:
        print(render_report(ctx.report))
        print(f"report artifact: {ctx.report_path}")
    return 0


def cmd_report(args: argparse.Namespace) -> int:
    art = ReportArtifact.load(args.path)
    if args.json:
        print(json.dumps({"kind": art.kind,
                          "schema_version": art.schema_version,
                          **art.to_payload()}, indent=2))
    else:
        print(render_report(art.report))
    return 0


def cmd_optimize(args: argparse.Namespace) -> int:
    root = _resolve_root(args)
    if args.static:
        if args.report:
            print("optimize: --static uses static reachability; "
                  "--report does not apply", file=sys.stderr)
            return 2
        facade = SlimStart.static_baseline(
            args.app, root, variant=args.variant or "static")
    else:
        facade = SlimStart(args.app, root,
                           variant=args.variant or "slimstart",
                           stages=[OptimizeStage(mode="profile")])
        if args.report:
            facade.ctx.report_path = os.path.abspath(args.report)
    if args.measure:
        facade.add(ReplayStage(n_cold=args.n_cold))
    ctx = facade.run()
    out = {"variant_dir": ctx.variant_dir, **ctx.apply_summary}
    if "replay" in ctx.results:
        out["measured"] = {k: round(v, 3) if isinstance(v, float) else v
                          for k, v in ctx.results["replay"].items()}
    print(json.dumps(out, indent=2))
    return 0


def cmd_restore(args: argparse.Namespace) -> int:
    target = args.target
    if not os.path.isdir(target):
        root = _resolve_root(args)
        target = os.path.join(root, "variants", args.target, args.variant)
        if not os.path.isdir(target):
            print(f"restore: no such directory or app variant: "
                  f"{args.target} (tried {target})", file=sys.stderr)
            return 2
    summary = restore_deployment(target)
    print(json.dumps({"target": target, **summary}, indent=2))
    return 0


def cmd_pool_serve(args: argparse.Namespace) -> int:
    from repro.pool.forkserver import ForkServer
    from repro.pool.policies import hot_set_from_report
    if args.app_dir:
        app_dir = args.app_dir
    else:
        root = _resolve_root(args)
        app_dir = os.path.join(root, "apps", args.app)
    preload: list[str] = []
    if args.report:
        preload = hot_set_from_report(load_report(args.report))
    rows = []
    with ForkServer(app_dir, preload=preload) as fs:
        print(f"zygote ready (pid {fs.ready.get('pid')}), preloaded: "
              f"{fs.ready.get('preloaded') or '(bare)'}")
        for i in range(args.requests):
            m = fs.exec(invocations=args.invocations, seed=args.seed + i)
            rows.append({"request": i, "init_ms": m["init_ms"],
                         "e2e_ms": m["e2e_cold_ms"],
                         "rss_mb": m["peak_rss_kb"] / 1024.0})
    _print_rows(rows, ["request", "init_ms", "e2e_ms", "rss_mb"])
    if rows:
        mean = sum(r["init_ms"] for r in rows) / len(rows)
        print(f"mean pool-start init: {mean:.1f} ms over {len(rows)} "
              f"forked instances")
    return 0


def cmd_fleet_replay(args: argparse.Namespace) -> int:
    from repro.pool.fleet import FleetManager
    from repro.pool.policies import (
        FixedSizePolicy, HistogramPolicy, IdleTimeoutPolicy,
        ProfileGuidedPolicy,
    )
    from repro.pool.simulator import AppProfile
    from repro.pool.trace import azure_synthetic_rows, trace_from_azure_rows

    if args.trace:
        trace = load_trace(args.trace)
        apps = sorted({r.app for r in trace})
    else:
        apps = [a for a in args.apps.split(",") if a]
        rows = azure_synthetic_rows(apps, minutes=args.minutes,
                                    peak_rpm=args.peak_rpm,
                                    seed=args.seed)
        trace = trace_from_azure_rows(rows, name="azure-synthetic")

    profiles = {app: AppProfile(app=app, cold_init_ms=args.cold_init_ms,
                                warm_init_ms=args.warm_init_ms,
                                invoke_ms=args.invoke_ms,
                                rss_mb=args.rss_mb,
                                zygote_rss_mb=args.zygote_rss_mb)
                for app in apps}
    if args.policy == "fixed":
        policy = FixedSizePolicy(size=2)
    elif args.policy == "histogram":
        policy = HistogramPolicy()
    elif args.policy == "profile":
        policy = ProfileGuidedPolicy()
        loaded = []
        for app in apps:
            path = os.path.join(args.reports_dir or "", f"{app}.json")
            if args.reports_dir and os.path.exists(path):
                policy.add_report(load_report(path))
                loaded.append(app)
        if args.reports_dir:
            print(f"profile-guided: loaded report artifacts for "
                  f"{loaded or 'no apps'}")
    else:
        policy = IdleTimeoutPolicy(timeout_s=args.idle_timeout_s)

    summary = FleetManager(profiles, policy,
                           budget_mb=args.budget_mb).replay(trace)
    print(json.dumps(summary.summary(), indent=2))
    _print_rows(summary.app_rows(),
                ["app", "requests", "cold_starts", "cold_ratio",
                 "p50_ms", "p99_ms", "max_instances"])
    return 0


def cmd_ci_check(args: argparse.Namespace) -> int:
    """The paper's CI/CD gate: does a fresh profile still agree with
    the deployed optimization?

    The profiler samples, so a package sitting exactly on the
    utilization threshold can flip between runs at small profiling
    budgets.  ``--retries N`` demands *persistent* drift: a mismatch is
    re-profiled up to N extra times and the check passes if any run
    matches the deployed defer set.
    """
    deployed = load_report(args.deployed)
    root = _resolve_root(args)
    dep_set = sorted(deployed.defer_targets)
    verdict: dict = {}
    for attempt in range(args.retries + 1):
        facade = SlimStart(args.app, root, stages=[
            ProfileStage(instances=args.instances,
                         invocations=args.invocations,
                         seed0=1000 + 100 * attempt),
            AnalyzeStage(save=bool(args.out)),
        ])
        if args.out:
            facade.ctx.report_path = os.path.abspath(args.out)
        ctx = facade.run()
        new_set = sorted(ctx.report.defer_targets)
        verdict = {
            "app": args.app,
            "attempt": attempt + 1,
            "deployed_defer_targets": dep_set,
            "fresh_defer_targets": new_set,
            "newly_deferred": sorted(set(new_set) - set(dep_set)),
            "no_longer_deferred": sorted(set(dep_set) - set(new_set)),
            "match": dep_set == new_set,
        }
        if verdict["match"]:
            break
        if attempt < args.retries:
            print(f"ci-check: defer set diverged on attempt "
                  f"{attempt + 1}; re-profiling to rule out sampling "
                  f"noise", file=sys.stderr)
    print(json.dumps(verdict, indent=2))
    if verdict["match"]:
        print("ci-check: PASS — deployed defer set matches the fresh "
              "profile")
        return 0
    print("ci-check: FAIL — workload drifted; re-run "
          "`python -m repro optimize` and redeploy", file=sys.stderr)
    return 1


# ---------------------------------------------------------------------------
# Parser
# ---------------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="SLIMSTART profile-guided cold-start optimization")
    sub = ap.add_subparsers(dest="command", required=True)

    def add_root(p: argparse.ArgumentParser) -> None:
        p.add_argument("--root", default=None,
                       help="benchsuite root (default: generated "
                            ".benchsuite)")

    def add_profiling(p: argparse.ArgumentParser) -> None:
        p.add_argument("--instances", type=int, default=2,
                       help="profiled cold instances (default 2)")
        p.add_argument("--invocations", type=int, default=60,
                       help="invocations per instance (default 60)")

    p = sub.add_parser("profile",
                       help="profile an app and save the report artifact")
    p.add_argument("app")
    add_root(p)
    add_profiling(p)
    p.add_argument("--out", default=None,
                   help="report artifact path (default "
                        "<root>/reports/<app>.json)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable summary instead of the table")
    p.set_defaults(func=cmd_profile)

    p = sub.add_parser("report", help="render a saved report artifact")
    p.add_argument("path")
    p.add_argument("--json", action="store_true",
                   help="dump the versioned payload as JSON")
    p.set_defaults(func=cmd_report)

    p = sub.add_parser("optimize",
                       help="apply deferred imports to a variant copy")
    p.add_argument("app")
    add_root(p)
    p.add_argument("--report", default=None,
                   help="report artifact (default "
                        "<root>/reports/<app>.json)")
    p.add_argument("--static", action="store_true",
                   help="FaaSLight-style static baseline (no profile)")
    p.add_argument("--variant", default=None,
                   help="variant name under <root>/variants/<app>/ "
                        "(default: slimstart, or static with --static)")
    p.add_argument("--measure", action="store_true",
                   help="re-measure baseline vs optimized cold starts")
    p.add_argument("--n-cold", type=int, default=3,
                   help="cold starts per side for --measure")
    p.set_defaults(func=cmd_optimize)

    p = sub.add_parser("restore",
                       help="undo an optimization (.orig backups)")
    p.add_argument("target", help="deployment directory or app name")
    add_root(p)
    p.add_argument("--variant", default="slimstart")
    p.set_defaults(func=cmd_restore)

    pool = sub.add_parser("pool", help="warm-pool operations")
    pool_sub = pool.add_subparsers(dest="pool_command", required=True)
    p = pool_sub.add_parser("serve",
                            help="boot a zygote and serve fork starts")
    p.add_argument("app", nargs="?", default=None,
                   help="benchsuite app name (or use --app-dir)")
    p.add_argument("--app-dir", default=None,
                   help="explicit deployed app directory")
    add_root(p)
    p.add_argument("--report", default=None,
                   help="report artifact for the pre-import hot set")
    p.add_argument("--requests", type=int, default=5)
    p.add_argument("--invocations", type=int, default=1)
    p.add_argument("--seed", type=int, default=100)
    p.set_defaults(func=cmd_pool_serve)

    fleet = sub.add_parser("fleet", help="multi-app fleet operations")
    fleet_sub = fleet.add_subparsers(dest="fleet_command", required=True)
    p = fleet_sub.add_parser("replay",
                             help="replay a trace through the simulated "
                                  "fleet")
    p.add_argument("--trace", default=None,
                   help="trace artifact JSON (default: synthetic "
                        "Azure-style trace over --apps)")
    p.add_argument("--apps", default="graph_bfs,sentiment_analysis_r,echo")
    p.add_argument("--minutes", type=int, default=30)
    p.add_argument("--peak-rpm", type=float, default=60.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--budget-mb", type=float, default=512.0)
    p.add_argument("--policy", default="profile",
                   choices=["fixed", "idle", "histogram", "profile"])
    p.add_argument("--idle-timeout-s", type=float, default=600.0)
    p.add_argument("--reports-dir", default=None,
                   help="directory of per-app report artifacts for the "
                        "profile-guided policy")
    p.add_argument("--cold-init-ms", type=float, default=400.0)
    p.add_argument("--warm-init-ms", type=float, default=40.0)
    p.add_argument("--invoke-ms", type=float, default=30.0)
    p.add_argument("--rss-mb", type=float, default=128.0)
    p.add_argument("--zygote-rss-mb", type=float, default=96.0)
    p.set_defaults(func=cmd_fleet_replay)

    p = sub.add_parser("ci-check",
                       help="re-profile and compare against the deployed "
                            "report (exit 1 on drift)")
    p.add_argument("app")
    p.add_argument("--deployed", required=True,
                   help="the report artifact the deployment was "
                        "optimized from")
    add_root(p)
    add_profiling(p)
    p.add_argument("--out", default=None,
                   help="save the fresh report artifact here (for CI "
                        "artifact upload)")
    p.add_argument("--retries", type=int, default=0,
                   help="re-profile a mismatch up to N times; fail "
                        "only on persistent drift (default 0)")
    p.set_defaults(func=cmd_ci_check)

    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if getattr(args, "func", None) is cmd_pool_serve \
            and not (args.app or args.app_dir):
        print("pool serve: need an app name or --app-dir",
              file=sys.stderr)
        return 2
    try:
        return args.func(args)
    except ArtifactError as exc:
        print(f"artifact error: {exc}", file=sys.stderr)
        return 2
    except FileNotFoundError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    except KeyboardInterrupt:
        return 130
    except Exception as exc:
        # exit code 1 is reserved for ci-check divergence; any other
        # failure (broken profiling run, dead zygote, ...) must not be
        # mistaken for workload drift by a CI wrapper
        import traceback
        traceback.print_exc()
        print(f"error: {exc}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
