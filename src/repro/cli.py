"""``python -m repro`` — the SLIMSTART workflow as one CLI.

The paper's pitch is CI/CD integration: one command a pipeline job can
run per workload.  Every subcommand is a thin shell over
:mod:`repro.api` (stages + versioned artifacts), so the CLI, the
benchmarks and library callers share exactly one implementation:

    profile APP        profile + analyze → versioned report artifact
    report PATH        render a saved report artifact (Tables IV/V)
    optimize APP       AST deferred-import rewrite → variant deployment
    restore TARGET     undo an optimization from the .orig backups
    pool serve         boot a profile-guided zygote, serve fork starts
    fleet replay       replay a trace through the simulated fleet
                       (--real: end-to-end over a live ZygoteFleet)
    fleet serve        long-running daemon: bounded queues with
                       backpressure, rewarm timer, SIGTERM drain,
                       fleet_summary artifact on shutdown
    cluster replay     cluster-scale simulation: N nodes, one router,
                       placement-strategy comparison (--compare)
    cluster serve      one node agent: the fleet daemon behind a
                       length-prefixed-frame TCP socket
    cluster route      the global router: place apps on live node
                       agents, stream a trace, merge the ledgers
    obs report PATH    cold-start anatomy from a trace_events artifact
                       (per-phase p50/p99, top imports, --flame folded
                       stacks for flamegraph.pl)
    obs top            live per-app console from a daemon's /metrics
                       endpoint (or a metrics textfile)
    ci-check APP       re-profile; exit 1 if the defer set diverged
                       from the deployed report (the paper's CI gate)
    docs               (re)generate docs/cli.md from this parser;
                       --check exits 1 on drift (the CI docs gate)

``fleet serve``/``fleet replay`` grow the observability surface:
``--trace-out`` records spans and saves a ``trace_events`` artifact on
exit, ``--metrics-port`` (serve) exposes Prometheus text on a stdlib
HTTP endpoint, ``--log-level``/``--log-json`` shape the structured
stderr log (see docs/observability.md).

Exit codes: 0 ok / check passed, 1 ci-check divergence, 2 usage or
artifact errors (bad/missing files, schema violations).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional, Sequence

from repro.api import (
    AnalyzeStage,
    ArtifactError,
    OptimizeStage,
    ProfileStage,
    ReplayStage,
    ReportArtifact,
    SlimStart,
    load_report,
    load_trace,
    restore_deployment,
)
from repro.api.render import table
from repro.benchsuite.genlibs import build_suite
from repro.core.profiler.report import render_report


def _resolve_root(args: argparse.Namespace) -> str:
    """--root as given, else the (lazily generated) benchsuite root."""
    return args.root or build_suite()


def _print_rows(rows: Sequence[dict], cols: Sequence[str]) -> None:
    if rows:
        print(table(rows, list(cols)))


# ---------------------------------------------------------------------------
# Subcommands
# ---------------------------------------------------------------------------

def cmd_profile(args: argparse.Namespace) -> int:
    root = _resolve_root(args)
    facade = SlimStart(args.app, root, stages=[
        ProfileStage(instances=args.instances,
                     invocations=args.invocations),
        AnalyzeStage(),
    ])
    if args.out:
        facade.ctx.report_path = os.path.abspath(args.out)
    ctx = facade.run()
    if args.json:
        print(json.dumps(ctx.results["analyze"], indent=2))
    else:
        print(render_report(ctx.report))
        print(f"report artifact: {ctx.report_path}")
    return 0


def cmd_report(args: argparse.Namespace) -> int:
    art = ReportArtifact.load(args.path)
    if args.json:
        print(json.dumps({"kind": art.kind,
                          "schema_version": art.schema_version,
                          **art.to_payload()}, indent=2))
    else:
        print(render_report(art.report))
    return 0


def cmd_optimize(args: argparse.Namespace) -> int:
    root = _resolve_root(args)
    if args.static:
        if args.report:
            print("optimize: --static uses static reachability; "
                  "--report does not apply", file=sys.stderr)
            return 2
        facade = SlimStart.static_baseline(
            args.app, root, variant=args.variant or "static")
    else:
        facade = SlimStart(args.app, root,
                           variant=args.variant or "slimstart",
                           stages=[OptimizeStage(mode="profile")])
        if args.report:
            facade.ctx.report_path = os.path.abspath(args.report)
    if args.measure:
        facade.add(ReplayStage(n_cold=args.n_cold))
    ctx = facade.run()
    out = {"variant_dir": ctx.variant_dir, **ctx.apply_summary}
    if "replay" in ctx.results:
        out["measured"] = {k: round(v, 3) if isinstance(v, float) else v
                          for k, v in ctx.results["replay"].items()}
    print(json.dumps(out, indent=2))
    return 0


def cmd_restore(args: argparse.Namespace) -> int:
    target = args.target
    if not os.path.isdir(target):
        root = _resolve_root(args)
        target = os.path.join(root, "variants", args.target, args.variant)
        if not os.path.isdir(target):
            print(f"restore: no such directory or app variant: "
                  f"{args.target} (tried {target})", file=sys.stderr)
            return 2
    summary = restore_deployment(target)
    print(json.dumps({"target": target, **summary}, indent=2))
    return 0


def cmd_pool_serve(args: argparse.Namespace) -> int:
    import contextlib
    import time as _time

    from repro.pool.forkserver import BaseZygote, ForkServer
    from repro.pool.policies import hot_set_from_report
    if args.app_dir:
        app_dir = args.app_dir
    else:
        root = _resolve_root(args)
        app_dir = os.path.join(root, "apps", args.app)
    preload: list[str] = []
    if args.report:
        preload = hot_set_from_report(load_report(args.report))
    rows = []
    with contextlib.ExitStack() as stack:
        base = None
        if args.shared_base:
            # two-tier demo for one app: the hot set lives in a base
            # zygote and the app zygote is forked from it — its boot
            # is fork + (empty) delta instead of interpreter + hot set
            base = stack.enter_context(BaseZygote(
                preload=preload,
                search_paths=[os.path.join(app_dir, "libs")]))
            t0 = _time.perf_counter()
            fs = stack.enter_context(
                ForkServer(app_dir, preload=[], base=base))
            spawn_ms = (_time.perf_counter() - t0) * 1e3
            print(f"base zygote pid {base.ready.get('pid')} preloaded "
                  f"{base.ready.get('preloaded') or '(bare)'}; app "
                  f"zygote forked from base in {spawn_ms:.1f} ms")
        else:
            fs = stack.enter_context(ForkServer(app_dir, preload=preload))
        print(f"zygote ready (pid {fs.ready.get('pid')}), preloaded: "
              f"{fs.ready.get('preloaded') or '(bare)'}")
        for i in range(args.requests):
            m = fs.exec(invocations=args.invocations, seed=args.seed + i)
            rows.append({"request": i, "init_ms": m["init_ms"],
                         "e2e_ms": m["e2e_cold_ms"],
                         "rss_mb": m["peak_rss_kb"] / 1024.0})
    _print_rows(rows, ["request", "init_ms", "e2e_ms", "rss_mb"])
    if rows:
        mean = sum(r["init_ms"] for r in rows) / len(rows)
        print(f"mean pool-start init: {mean:.1f} ms over {len(rows)} "
              f"forked instances")
    return 0


def _fleet_trace(args: argparse.Namespace):
    """The replay workload: a saved trace artifact or a synthetic
    Azure-style one over ``--apps``.  ``--flip-popularity`` reverses
    the Zipf app order mid-trace (the canonical drift scenario for
    ``--adaptive``).  Returns (trace, apps)."""
    from repro.pool.trace import (
        azure_flip_rows, azure_synthetic_rows, trace_from_azure_rows,
    )
    if args.trace:
        trace = load_trace(args.trace)
        apps = sorted({r.app for r in trace})
    else:
        apps = [a for a in args.apps.split(",") if a]
        if getattr(args, "flip_popularity", False):
            rows = azure_flip_rows(apps, minutes=args.minutes,
                                   peak_rpm=args.peak_rpm,
                                   flip_minute=getattr(
                                       args, "flip_minute", None),
                                   seed=args.seed)
            trace = trace_from_azure_rows(rows, name="azure-flip")
        else:
            rows = azure_synthetic_rows(apps, minutes=args.minutes,
                                        peak_rpm=args.peak_rpm,
                                        seed=args.seed)
            trace = trace_from_azure_rows(rows, name="azure-synthetic")
    return trace, apps


def _adaptive_config(args: argparse.Namespace):
    """--drift-* knobs -> AdaptiveConfig (None without --adaptive)."""
    if not getattr(args, "adaptive", False):
        return None
    from repro.core.adaptive import AdaptiveConfig, DriftConfig
    return AdaptiveConfig(drift=DriftConfig(
        window_s=args.drift_window_s, epsilon=args.drift_epsilon))


def _save_drift_report(args: argparse.Namespace, loop, source: str):
    """Persist the loop's drift_report artifact when --drift-out."""
    if loop is None or not getattr(args, "drift_out", None):
        return
    from repro.api.artifacts import save_drift_report
    path = os.path.abspath(args.drift_out)
    save_drift_report(loop.drift_report_payload(source=source), path)
    print(f"drift_report artifact: {path}")


def _fleet_policy(args: argparse.Namespace, apps: Sequence[str]):
    from repro.pool.policies import (
        FixedSizePolicy, HistogramPolicy, IdleTimeoutPolicy,
        ProfileGuidedPolicy,
    )
    if args.policy == "fixed":
        return FixedSizePolicy(size=2)
    if args.policy == "histogram":
        return HistogramPolicy()
    if args.policy == "profile":
        policy = ProfileGuidedPolicy()
        loaded = []
        for app in apps:
            path = os.path.join(args.reports_dir or "", f"{app}.json")
            if args.reports_dir and os.path.exists(path):
                policy.add_report(load_report(path))
                loaded.append(app)
        if args.reports_dir:
            print(f"profile-guided: loaded report artifacts for "
                  f"{loaded or 'no apps'}", file=sys.stderr)
        return policy
    return IdleTimeoutPolicy(timeout_s=args.idle_timeout_s)


def _fleet_profiles(args: argparse.Namespace, apps: Sequence[str]):
    from repro.pool.simulator import AppProfile
    return {app: AppProfile(app=app, cold_init_ms=args.cold_init_ms,
                            warm_init_ms=args.warm_init_ms,
                            invoke_ms=args.invoke_ms,
                            rss_mb=args.rss_mb,
                            zygote_rss_mb=args.zygote_rss_mb,
                            zygote_private_mb=args.zygote_private_mb)
            for app in apps}


def _shared_base_mb(args: argparse.Namespace) -> float:
    """The simulated base zygote's resident MB (0 = two-tier off)."""
    return args.shared_base_mb if args.shared_base else 0.0


def _queue_config(args: argparse.Namespace):
    from repro.pool.fleet import QueueConfig
    return QueueConfig(depth=args.queue_depth,
                       max_concurrency=args.max_concurrency,
                       shed_policy=args.shed_policy)


def _real_fleet(args: argparse.Namespace, apps: Sequence[str], **extra):
    """A (not yet started) ZygoteFleet over deployed benchsuite apps,
    with per-app report artifacts from --reports-dir as preload sets.
    ``extra`` passes chaos/hardening knobs (fault_hook, breaker, ...)
    straight through to the ZygoteFleet constructor."""
    from repro.pool.fleet import ZygoteFleet
    root = _resolve_root(args)
    app_dirs = {}
    for app in apps:
        d = os.path.join(root, "apps", app)
        if not os.path.isdir(d):
            raise FileNotFoundError(f"no deployed app directory: {d}")
        app_dirs[app] = d
    reports = {}
    for app in apps:
        path = os.path.join(args.reports_dir or "", f"{app}.json")
        if args.reports_dir and os.path.exists(path):
            reports[app] = path  # as_report() resolves artifact paths
    budget = args.budget_mb if args.budget_mb > 0 else None
    return ZygoteFleet(app_dirs, budget_mb=budget, reports=reports,
                       shared_base=args.shared_base,
                       base_min_apps=args.base_min_apps, **extra)


def _chaos_plan(args: argparse.Namespace, apps: Sequence[str]):
    """Resolve --chaos into a FaultPlan: the literal ``storm`` builds
    the canonical crash-storm plan over the replayed apps, anything
    else is a path to a saved ``chaos_plan`` JSON file."""
    from repro.pool.chaos import FaultPlan
    if args.chaos == "storm":
        return FaultPlan.storm(list(apps), seed=args.chaos_seed)
    return FaultPlan.load(args.chaos)


def _chaos_replay(args: argparse.Namespace, trace, apps) -> int:
    """``fleet replay --real --chaos``: the seeded fault-injection
    path.  Routes the trace through the daemon (bounded queues, drain
    accounting) over a hardened ZygoteFleet with the injector as its
    fault_hook; emits fleet_summary + chaos_report artifacts and exits
    non-zero when the request-conservation invariant breaks."""
    import signal

    from repro.api.artifacts import save_chaos_report, save_fleet_summary
    from repro.pool.chaos import FaultInjector, chaos_report_payload
    from repro.pool.daemon import FleetDaemon, RealFleetBackend
    from repro.pool.fleet import BreakerConfig

    plan = _chaos_plan(args, apps)
    injector = FaultInjector(plan)
    breaker = BreakerConfig(max_failures=args.breaker_max_failures,
                            cooldown_s=args.breaker_cooldown_s)
    fleet = _real_fleet(args, apps,
                        fault_hook=injector,
                        breaker=breaker,
                        boot_backoff_s=args.boot_backoff_s,
                        revive_on_dispatch=True,
                        timeout_s=args.dispatch_timeout_s)
    queue = (_queue_config(args) if args.queue_depth >= 0
             else _queue_config(argparse.Namespace(
                 queue_depth=16, max_concurrency=args.max_concurrency,
                 shed_policy=args.shed_policy)))
    backend = RealFleetBackend(fleet, queue=queue,
                               reports_dir=args.reports_dir)
    daemon = FleetDaemon(backend, fault_hook=injector)
    signal.signal(signal.SIGTERM, daemon.request_shutdown)
    signal.signal(signal.SIGINT, daemon.request_shutdown)

    daemon.start(trace.name)
    payload = daemon.run_trace(trace, pace=args.chaos_pace)
    report = chaos_report_payload(injector, summary=payload,
                                  recoveries=fleet.recoveries)
    print(json.dumps({k: v for k, v in payload.items()
                      if k != "per_app"}, indent=2))
    _print_rows(payload["per_app"],
                ["app", "requests", "cold_starts", "sheds", "flushed",
                 "abandoned", "degraded", "p99_ms"])
    inv = report["invariant"]
    print(f"chaos: injected={len(injector.injected)} "
          f"pending={len(injector.pending())} "
          f"recoveries={fleet.recoveries} "
          f"invariant={'holds' if inv['holds'] else 'BROKEN'}",
          file=sys.stderr)
    if args.out:
        save_fleet_summary(payload, os.path.abspath(args.out))
        print(f"fleet_summary artifact: {os.path.abspath(args.out)}")
    if args.chaos_report:
        save_chaos_report(report, os.path.abspath(args.chaos_report))
        print(f"chaos_report artifact: "
              f"{os.path.abspath(args.chaos_report)}")
    _obs_save_capture(args, "fleet-replay",
                      meta={"trace": trace.name, "apps": list(apps),
                            "real": True, "chaos": args.chaos,
                            "chaos_seed": args.chaos_seed})
    return 0 if inv["holds"] else 1


def cmd_fleet_replay(args: argparse.Namespace) -> int:
    from repro.api.artifacts import save_fleet_summary
    from repro.pool.fleet import FleetManager

    _obs_setup(args)
    trace, apps = _fleet_trace(args)
    if args.chaos:
        if not args.real:
            print("fleet replay --chaos requires --real (faults are "
                  "injected into live zygote processes)",
                  file=sys.stderr)
            return 2
        return _chaos_replay(args, trace, apps)
    adaptive_cfg = _adaptive_config(args)
    if args.real:
        with _real_fleet(args, apps) as fleet:
            loop = (fleet.make_adaptive_loop(config=adaptive_cfg)
                    if args.adaptive else None)
            rows = fleet.replay(trace, limit=args.limit, adaptive=loop)
        payload = fleet.last_summary
        print(json.dumps({k: v for k, v in payload.items()
                          if k != "per_app"}, indent=2))
        _print_rows(rows, ["app", "requests", "pool_starts",
                           "cold_starts", "cold_ratio", "pool_init_ms",
                           "cold_init_ms", "p99_ms"])
        _save_drift_report(args, loop, "replay-real")
    else:
        queue = _queue_config(args) if args.queue_depth >= 0 else None
        manager = FleetManager(_fleet_profiles(args, apps),
                               _fleet_policy(args, apps),
                               budget_mb=args.budget_mb,
                               queue=queue,
                               shared_base_mb=_shared_base_mb(args))
        loop = None
        if args.adaptive:
            from repro.pool.daemon import make_sim_adaptive_loop
            loop = make_sim_adaptive_loop(manager, config=adaptive_cfg)
            manager.begin(trace.name)
            for req in trace:
                # drift windows close in trace time, so a confirmed
                # re-optimization lands before the next offer — the
                # hot-swap is shed-free by construction
                loop.observe_request(req.app, req.handler, t=req.t)
                manager.offer(req)
            summary = manager.finish(trace.duration_s)
            loop.flush(t=trace.duration_s)
        else:
            summary = manager.replay(trace)
        payload = summary.artifact_payload(source="replay-sim")
        if loop is not None:
            payload["adaptive"] = loop.summary()
        print(json.dumps(summary.summary(), indent=2))
        _print_rows(summary.app_rows(),
                    ["app", "requests", "cold_starts", "cold_ratio",
                     "p50_ms", "p99_ms", "max_instances", "sheds",
                     "queue_wait_p99_ms"])
        _save_drift_report(args, loop, "replay-sim")
    if args.out:
        save_fleet_summary(payload, os.path.abspath(args.out))
        print(f"fleet_summary artifact: {os.path.abspath(args.out)}")
    _obs_save_capture(args, "fleet-replay",
                      meta={"trace": trace.name, "apps": apps,
                            "real": bool(args.real)})
    return 0


def cmd_fleet_serve(args: argparse.Namespace) -> int:
    """The long-running daemon (see docs/daemon.md): bounded per-app
    queues with backpressure, a rewarm timer re-loading deployed report
    artifacts into the warm fleet, SIGTERM graceful drain, and a
    ``fleet_summary`` artifact on the way out."""
    import signal

    from repro.pool.daemon import (
        FleetDaemon, RealFleetBackend, SimFleetBackend,
    )
    from repro.pool.fleet import FleetManager

    _obs_setup(args)
    queue = _queue_config(args)
    trace = None
    if not args.stdin:
        trace, apps = _fleet_trace(args)
    else:
        apps = [a for a in args.apps.split(",") if a]
        if not apps:
            print("fleet serve --stdin: need --apps", file=sys.stderr)
            return 2

    adaptive_cfg = _adaptive_config(args)
    loop = None
    if args.sim:
        manager = FleetManager(_fleet_profiles(args, apps),
                               _fleet_policy(args, apps),
                               budget_mb=args.budget_mb, queue=queue,
                               shared_base_mb=_shared_base_mb(args))
        if args.adaptive:
            from repro.pool.daemon import make_sim_adaptive_loop
            loop = make_sim_adaptive_loop(manager, config=adaptive_cfg)
        backend = SimFleetBackend(manager, reports_dir=args.reports_dir,
                                  adaptive=loop)
    else:
        fleet = _real_fleet(args, apps)
        if args.adaptive:
            loop = fleet.make_adaptive_loop(config=adaptive_cfg)
        backend = RealFleetBackend(fleet, queue=queue,
                                   reports_dir=args.reports_dir,
                                   adaptive=loop)

    daemon = FleetDaemon(backend,
                         rewarm_interval_s=args.rewarm_interval_s,
                         summary_path=(os.path.abspath(args.summary_out)
                                       if args.summary_out else None),
                         drain_timeout_s=args.drain_timeout_s)
    signal.signal(signal.SIGTERM, daemon.request_shutdown)
    signal.signal(signal.SIGINT, daemon.request_shutdown)

    metrics_server = None
    if args.metrics_port is not None:
        from repro.obs.exposition import MetricsServer
        metrics_server = MetricsServer(port=args.metrics_port)
        metrics_server.start()

    try:
        boot = daemon.start(trace.name if trace is not None else "live")
        ready = {"ok": True, "event": "ready", **boot}
        if metrics_server is not None:
            ready["metrics_url"] = metrics_server.url
        print(json.dumps(ready), file=sys.stderr, flush=True)
        if args.stdin:
            payload = daemon.run_stdin()
        else:
            payload = daemon.run_trace(trace, pace=args.pace)
            print(json.dumps({k: v for k, v in payload.items()
                              if k != "per_app"}, indent=2))
            _print_rows(payload["per_app"],
                        ["app", "requests", "cold_starts", "sheds",
                         "flushed", "p99_ms", "queue_wait_p99_ms"])
    finally:
        if metrics_server is not None:
            metrics_server.stop()
    if args.summary_out:
        print(f"fleet_summary artifact: "
              f"{os.path.abspath(args.summary_out)}", file=sys.stderr)
    _save_drift_report(args, loop,
                       "serve-sim" if args.sim else "serve-real")
    _obs_save_capture(args, "fleet-serve",
                      meta={"apps": apps, "sim": bool(args.sim),
                            "adaptive": bool(args.adaptive)})
    rewarm_errors = int(payload.get("rewarm_errors") or 0)
    if rewarm_errors:
        # rewarm-tick failures were swallowed into the daemon's ring
        # buffer during the run; a clean exit here would hide them
        print(f"fleet serve: {rewarm_errors} rewarm error(s) during "
              f"the run (see rewarm_errors in the summary)",
              file=sys.stderr)
        return 1
    return 0


def cmd_drift_status(args: argparse.Namespace) -> int:
    """Render a saved drift_report artifact: the detector config that
    was applied, every closed window's verdict, and the
    re-optimization actions the adaptive loop took."""
    from repro.api.artifacts import load_drift_report
    payload = load_drift_report(args.path)
    if args.json:
        print(json.dumps(payload, indent=2, sort_keys=True))
        return 0
    cfg = payload.get("config", {})
    print(f"drift report ({payload.get('source', '?')}): "
          f"{len(payload.get('windows', []))} windows, "
          f"{payload.get('fires', 0)} fired, "
          f"final score {payload.get('final_score', 0.0):.3f}")
    print(f"  config: window_s={cfg.get('window_s')} "
          f"epsilon={cfg.get('epsilon')} "
          f"noise_guard={cfg.get('noise_guard')} "
          f"sample_every={cfg.get('sample_every')}")
    overhead = payload.get("sampler_overhead_pct")
    if overhead is not None:
        print(f"  sampler overhead: {overhead:.2f}% of exec time")
    rows = []
    for w in payload.get("windows", []):
        rows.append({
            "t_end": round(w.get("t_end", 0.0), 1),
            "invocations": w.get("invocations", 0),
            "mix": round(w.get("mix_score", 0.0), 3),
            "miss": round(w.get("miss_score", 0.0), 3),
            "new_mods": round(w.get("new_module_score", 0.0), 3),
            "score": round(w.get("score", 0.0), 3),
            "fired": w.get("fired", False),
            "suppressed": w.get("suppressed", False),
        })
    if rows:
        _print_rows(rows, ["t_end", "invocations", "mix", "miss",
                           "new_mods", "score", "fired", "suppressed"])
    for act in payload.get("actions", []):
        applied = ", ".join(a["app"] for a in act.get("applied", []))
        print(f"  re-optimized @t={act.get('t', 0.0):.1f} "
              f"score={act.get('score', 0.0):.3f} "
              f"apps=[{applied}]"
              + (" base-swapped" if act.get("swapped") else "")
              + (f" ERROR: {act['error']}" if act.get("error") else ""))
    errors = payload.get("errors", [])
    if errors:
        print(f"  {len(errors)} error(s); last: {errors[-1]}")
    return 0


def _cluster_workload(args: argparse.Namespace):
    """The synthetic cluster workload shared by ``cluster replay``,
    ``cluster serve --sim`` and ``cluster route`` — same knobs, same
    seed, same workload on every side of a socket."""
    from repro.cluster import synthetic_cluster_workload
    return synthetic_cluster_workload(
        args.n_apps, n_families=args.families, seed=args.seed,
        minutes=args.minutes, peak_rpm=args.peak_rpm)


def _cluster_fault_hook(args: argparse.Namespace):
    """Build a FaultInjector from the cluster chaos flags:
    ``--node-loss-at N`` (chaos ``node_loss`` at the Nth route call),
    ``--kill-leader-at N`` (``router_loss`` at the election site) and
    ``--handoff-stall-at N`` (``handoff_stall`` at the handoff site).
    Returns None when no flag is set."""
    events = []
    from repro.pool.chaos import FaultEvent, FaultInjector, FaultPlan
    for at in getattr(args, "node_loss_at", None) or ():
        events.append(FaultEvent("node_loss", at=at))
    for at in getattr(args, "kill_leader_at", None) or ():
        events.append(FaultEvent("router_loss", at=at))
    for at in getattr(args, "handoff_stall_at", None) or ():
        events.append(FaultEvent("handoff_stall", at=at))
    if not events:
        return None
    plan = FaultPlan(events=events, seed=args.seed,
                     name="cli-cluster-chaos")
    return FaultInjector(plan, simulate=True)


def _print_cluster_summary(payload: dict) -> None:
    print(json.dumps({k: v for k, v in payload.items()
                      if k not in ("per_node", "placement",
                                   "migrations")}, indent=2))
    _print_rows(payload.get("per_node", []),
                ["node", "requests", "served", "cold_starts", "sheds",
                 "flushed", "p99_ms", "conservation_holds", "lost"])


def cmd_cluster_replay(args: argparse.Namespace) -> int:
    """Cluster-scale simulation: N nodes, one router, millions of
    synthetic invocations; ``--compare`` replays the same trace under
    every placement strategy at equal budgets."""
    from repro.api.artifacts import save_cluster_summary
    from repro.cluster import STRATEGIES, ClusterSimulator

    _obs_setup(args)
    wl = _cluster_workload(args)
    strategies = list(STRATEGIES) if args.compare else [args.strategy]
    results: dict[str, dict] = {}
    for strategy in strategies:
        sim = ClusterSimulator(
            wl, n_nodes=args.nodes, node_budget_mb=args.node_budget_mb,
            strategy=strategy, seed=args.seed,
            fault_hook=_cluster_fault_hook(args))
        results[strategy] = sim.replay(limit=args.limit)

    rows = [{"strategy": s,
             "requests": p["requests"],
             "cold_starts": p["cold_starts"],
             "cold_ratio": p["cold_start_ratio"],
             "p99_ms": p["p99_ms"],
             "sheds": p["sheds"],
             "memory_gb_s": p.get("memory_gb_s", 0.0),
             "conserves": p["conservation"]["holds"]}
            for s, p in results.items()]
    _print_rows(rows, ["strategy", "requests", "cold_starts",
                       "cold_ratio", "p99_ms", "sheds", "memory_gb_s",
                       "conserves"])
    payload = results[args.strategy]
    if not args.compare:
        _print_cluster_summary(payload)
    elif "hash" in results:
        beats = (results["sharing"]["cold_start_ratio"]
                 <= results["hash"]["cold_start_ratio"])
        print(f"sharing vs hash cold-start ratio: "
              f"{results['sharing']['cold_start_ratio']} vs "
              f"{results['hash']['cold_start_ratio']} -> "
              f"{'sharing wins' if beats else 'HASH WINS'}")
    if args.out:
        save_cluster_summary(payload, os.path.abspath(args.out))
        print(f"cluster_summary artifact: {os.path.abspath(args.out)}")
    _obs_save_capture(args, "cluster-replay",
                      meta={"nodes": args.nodes,
                            "strategies": strategies})
    if args.check and not all(p["conservation"]["holds"]
                              for p in results.values()):
        broken = [s for s, p in results.items()
                  if not p["conservation"]["holds"]]
        print(f"cluster replay --check: conservation BROKEN under "
              f"{broken}", file=sys.stderr)
        return 1
    return 0


def cmd_cluster_serve(args: argparse.Namespace) -> int:
    """One node agent: a fleet daemon behind a frame-protocol socket
    (see docs/cluster.md).  Prints a ready line with the bound port,
    serves until a shutdown frame / signal, then prints the node's
    fleet_summary payload."""
    import signal

    from repro.cluster import NodeAgent
    from repro.pool.daemon import RealFleetBackend, SimFleetBackend
    from repro.pool.fleet import FleetManager
    from repro.pool.policies import ProfileGuidedPolicy

    _obs_setup(args)
    queue = _queue_config(args)
    if args.sim:
        wl = _cluster_workload(args)
        apps = ([a for a in args.apps.split(",") if a]
                if args.apps else list(wl.apps))
        unknown = sorted(set(apps) - set(wl.apps))
        if unknown:
            print(f"cluster serve --sim: apps not in the synthetic "
                  f"workload: {unknown} (have app00..app"
                  f"{args.n_apps - 1:02d})", file=sys.stderr)
            return 2
        policy = ProfileGuidedPolicy()
        for app in apps:
            policy.add_report(wl.reports[app])
        manager = FleetManager(
            {a: wl.profiles[a] for a in apps}, policy,
            budget_mb=args.budget_mb, queue=queue)
        backend = SimFleetBackend(manager,
                                  reports_dir=args.reports_dir)
    else:
        apps = [a for a in args.apps.split(",") if a]
        if not apps:
            print("cluster serve: need --apps", file=sys.stderr)
            return 2
        backend = RealFleetBackend(_real_fleet(args, apps),
                                   queue=queue,
                                   reports_dir=args.reports_dir)

    agent = NodeAgent(
        backend, node_id=args.node_id, host=args.host, port=args.port,
        rewarm_interval_s=args.rewarm_interval_s,
        summary_path=(os.path.abspath(args.summary_out)
                      if args.summary_out else None),
        drain_timeout_s=args.drain_timeout_s,
        drain_on_disconnect=args.drain_on_disconnect)
    signal.signal(signal.SIGTERM,
                  lambda *_: agent.request_shutdown())
    signal.signal(signal.SIGINT,
                  lambda *_: agent.request_shutdown())
    boot = agent.start()
    # the ready line is the contract with launchers (tools/
    # cluster_smoke.py): one JSON object on stdout carrying the bound
    # port
    print(json.dumps({"ok": True, "event": "ready", **boot}),
          flush=True)
    payload = agent.serve_forever()
    print(json.dumps({k: v for k, v in payload.items()
                      if k != "per_app"}, indent=2))
    if args.summary_out:
        print(f"fleet_summary artifact: "
              f"{os.path.abspath(args.summary_out)}", file=sys.stderr)
    return 0


def cmd_cluster_route(args: argparse.Namespace) -> int:
    """The global router over live node agents: hello every node,
    place apps (sharing-aware by default), feed the trace over the
    sockets, then drain the nodes and merge their ledgers into one
    cluster_summary."""
    import time as _time

    from repro.api.artifacts import save_cluster_summary
    from repro.cluster import (ClusterRouter, NodeClient,
                               ReplicatedRouter, RetryPolicy)

    _obs_setup(args)
    addrs: dict[str, tuple] = {}
    for spec in args.nodes.split(","):
        spec = spec.strip()
        if not spec:
            continue
        try:
            node_id, addr = spec.split("=", 1)
            host, port = addr.rsplit(":", 1)
            addrs[node_id] = (host, int(port))
        except ValueError:
            print(f"cluster route: bad --nodes entry {spec!r} "
                  f"(want id=host:port)", file=sys.stderr)
            return 2
    if not addrs:
        print("cluster route: need --nodes id=host:port[,...]",
              file=sys.stderr)
        return 2
    retry = RetryPolicy.from_args(args)

    if args.trace:
        trace = load_trace(args.trace)
        hot_sets: dict = {}
        if args.reports_dir:
            from repro.pool.policies import hot_set_from_report
            for app in sorted({r.app for r in trace}):
                path = os.path.join(args.reports_dir, f"{app}.json")
                if os.path.exists(path):
                    hot_sets[app] = hot_set_from_report(
                        load_report(path))
    else:
        wl = _cluster_workload(args)
        trace, hot_sets = wl.trace, wl.hot_sets

    fault_hook = _cluster_fault_hook(args)
    if args.ha:
        router = ReplicatedRouter(
            addrs, strategy=args.strategy, hot_sets=hot_sets,
            seed=args.seed, retry=retry, standby_id=args.standby_id,
            lease_ttl_s=args.lease_ttl_s, fault_hook=fault_hook)
    else:
        clients = {node_id: NodeClient(node_id, host, port,
                                       retry=retry)
                   for node_id, (host, port) in sorted(addrs.items())}
        router = ClusterRouter(clients, strategy=args.strategy,
                               hot_sets=hot_sets, seed=args.seed,
                               retry=retry, fault_hook=fault_hook)
    placement = router.connect()
    print(f"placement over {len(addrs)} nodes: "
          f"{json.dumps(placement)}", file=sys.stderr)
    if args.leave_node and args.leave_node not in addrs:
        print(f"cluster route: --leave-node {args.leave_node!r} is "
              f"not in --nodes", file=sys.stderr)
        return 2

    routed = unplaced = 0
    left = False
    prev_t: Optional[float] = None
    for i, req in enumerate(trace):
        if args.limit is not None and i >= args.limit:
            break
        if args.leave_node and not left and routed >= args.leave_at:
            out = router.plan_leave(args.leave_node,
                                    warm=not args.cold_leave)
            left = True
            print(f"planned leave {args.leave_node}: "
                  f"{json.dumps(out)}", file=sys.stderr)
        if req.app not in router.placement:
            unplaced += 1  # no node deploys it: not admitted anywhere
            continue
        if args.pace > 0 and prev_t is not None:
            _time.sleep(max(0.0, (req.t - prev_t) * args.pace))
        prev_t = req.t
        router.route(req.app, req.handler)
        routed += 1
    if args.leave_node and not left:
        router.plan_leave(args.leave_node, warm=not args.cold_leave)
    payload = router.shutdown()
    payload["router"]["unplaced"] = unplaced
    _print_cluster_summary(payload)
    if unplaced:
        print(f"cluster route: {unplaced} arrivals had no deploying "
              f"node and were never admitted", file=sys.stderr)
    if args.out:
        save_cluster_summary(payload, os.path.abspath(args.out))
        print(f"cluster_summary artifact: {os.path.abspath(args.out)}")
    _obs_save_capture(args, "cluster-route",
                      meta={"nodes": sorted(addrs),
                            "strategy": args.strategy,
                            "routed": routed})
    if args.check and not payload["conservation"]["holds"]:
        print("cluster route --check: conservation BROKEN",
              file=sys.stderr)
        return 1
    return 0


def _obs_setup(args: argparse.Namespace) -> None:
    """Apply the shared observability knobs (logging + tracing)."""
    from repro.obs.log import configure as configure_log
    configure_log(level=args.log_level, json_mode=args.log_json)
    if getattr(args, "trace_out", None):
        from repro.obs.tracing import configure_tracing
        configure_tracing(enabled=True)


def _obs_save_capture(args: argparse.Namespace, source: str,
                      meta: Optional[dict] = None) -> None:
    """Save the tracer's spans + a metrics snapshot as a versioned
    ``trace_events`` artifact (the ``--trace-out`` contract)."""
    if not getattr(args, "trace_out", None):
        return
    from repro.api.artifacts import save_trace_events
    from repro.obs.metrics import default_registry
    from repro.obs.tracing import get_tracer
    tracer = get_tracer()
    spans = tracer.snapshot()
    path = os.path.abspath(args.trace_out)
    save_trace_events(spans, path,
                      metrics=default_registry().snapshot(),
                      meta={"source": source, "spans": len(spans),
                            "dropped": tracer.dropped, **(meta or {})})
    print(f"trace_events artifact: {path} ({len(spans)} spans)",
          file=sys.stderr)


def cmd_obs_report(args: argparse.Namespace) -> int:
    from repro.api.artifacts import load_trace_events
    from repro.obs.anatomy import (
        folded_stacks, phase_breakdown, top_imports,
    )
    from repro.obs.anatomy import render_report as render_anatomy
    art = load_trace_events(args.path)
    if args.flame:
        lines = folded_stacks(art.spans)
        flame = os.path.abspath(args.flame)
        with open(flame, "w") as fh:
            fh.write("\n".join(lines) + ("\n" if lines else ""))
        print(f"folded stacks: {flame} ({len(lines)} frames) — "
              f"render with flamegraph.pl", file=sys.stderr)
    if args.json:
        print(json.dumps({
            "meta": art.meta,
            "phases": phase_breakdown(art.spans),
            "top_imports": top_imports(art.spans, n=args.top),
        }, indent=2))
    else:
        print(render_anatomy(art.spans, top_n=args.top, meta=art.meta))
    return 0


def cmd_obs_top(args: argparse.Namespace) -> int:
    from repro.obs.console import run_top
    if args.url:
        url = args.url
    elif args.port is not None:
        url = f"http://127.0.0.1:{args.port}/metrics"
    elif args.file:
        url = args.file
    else:
        print("obs top: need --url, --port or --file", file=sys.stderr)
        return 2
    return run_top(url, interval_s=args.interval,
                   iterations=args.iterations, clear=not args.no_clear)


def cmd_docs(args: argparse.Namespace) -> int:
    """Generate (or verify) the committed CLI reference."""
    from repro.api.render import cli_reference_markdown
    generated = cli_reference_markdown(build_parser())
    out = os.path.abspath(args.out)
    if args.check:
        try:
            committed = open(out).read()
        except OSError:
            print(f"docs --check: {args.out} is missing; run "
                  f"`python -m repro docs` and commit it",
                  file=sys.stderr)
            return 1
        if committed != generated:
            print(f"docs --check: {args.out} has drifted from the "
                  f"argparse tree; run `python -m repro docs` and "
                  f"commit the result", file=sys.stderr)
            return 1
        print(f"docs --check: {args.out} is up to date")
        return 0
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as fh:
        fh.write(generated)
    print(f"wrote {args.out}")
    return 0


def cmd_ci_check(args: argparse.Namespace) -> int:
    """The paper's CI/CD gate: does a fresh profile still agree with
    the deployed optimization?

    The profiler samples, so a package sitting exactly on the
    utilization threshold can flip between runs at small profiling
    budgets.  ``--retries N`` demands *persistent* drift: a mismatch is
    re-profiled up to N extra times and the check passes if any run
    matches the deployed defer set.
    """
    deployed = load_report(args.deployed)
    root = _resolve_root(args)
    dep_set = sorted(deployed.defer_targets)
    verdict: dict = {}
    for attempt in range(args.retries + 1):
        facade = SlimStart(args.app, root, stages=[
            ProfileStage(instances=args.instances,
                         invocations=args.invocations,
                         seed0=1000 + 100 * attempt),
            AnalyzeStage(save=bool(args.out)),
        ])
        if args.out:
            facade.ctx.report_path = os.path.abspath(args.out)
        ctx = facade.run()
        new_set = sorted(ctx.report.defer_targets)
        verdict = {
            "app": args.app,
            "attempt": attempt + 1,
            "deployed_defer_targets": dep_set,
            "fresh_defer_targets": new_set,
            "newly_deferred": sorted(set(new_set) - set(dep_set)),
            "no_longer_deferred": sorted(set(dep_set) - set(new_set)),
            "match": dep_set == new_set,
        }
        if verdict["match"]:
            break
        if attempt < args.retries:
            print(f"ci-check: defer set diverged on attempt "
                  f"{attempt + 1}; re-profiling to rule out sampling "
                  f"noise", file=sys.stderr)
    print(json.dumps(verdict, indent=2))
    if verdict["match"]:
        print("ci-check: PASS — deployed defer set matches the fresh "
              "profile")
        return 0
    print("ci-check: FAIL — workload drifted; re-run "
          "`python -m repro optimize` and redeploy", file=sys.stderr)
    return 1


# ---------------------------------------------------------------------------
# Parser
# ---------------------------------------------------------------------------

def build_parser() -> argparse.ArgumentParser:
    from repro.cluster.ha import add_retry_flags

    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="SLIMSTART profile-guided cold-start optimization")
    sub = ap.add_subparsers(dest="command", required=True)

    def add_root(p: argparse.ArgumentParser) -> None:
        p.add_argument("--root", default=None,
                       help="benchsuite root (default: generated "
                            ".benchsuite)")

    def add_profiling(p: argparse.ArgumentParser) -> None:
        p.add_argument("--instances", type=int, default=2,
                       help="profiled cold instances (default 2)")
        p.add_argument("--invocations", type=int, default=60,
                       help="invocations per instance (default 60)")

    p = sub.add_parser("profile",
                       help="profile an app and save the report artifact")
    p.add_argument("app")
    add_root(p)
    add_profiling(p)
    p.add_argument("--out", default=None,
                   help="report artifact path (default "
                        "<root>/reports/<app>.json)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable summary instead of the table")
    p.set_defaults(func=cmd_profile)

    p = sub.add_parser("report", help="render a saved report artifact")
    p.add_argument("path")
    p.add_argument("--json", action="store_true",
                   help="dump the versioned payload as JSON")
    p.set_defaults(func=cmd_report)

    p = sub.add_parser("optimize",
                       help="apply deferred imports to a variant copy")
    p.add_argument("app")
    add_root(p)
    p.add_argument("--report", default=None,
                   help="report artifact (default "
                        "<root>/reports/<app>.json)")
    p.add_argument("--static", action="store_true",
                   help="FaaSLight-style static baseline (no profile)")
    p.add_argument("--variant", default=None,
                   help="variant name under <root>/variants/<app>/ "
                        "(default: slimstart, or static with --static)")
    p.add_argument("--measure", action="store_true",
                   help="re-measure baseline vs optimized cold starts")
    p.add_argument("--n-cold", type=int, default=3,
                   help="cold starts per side for --measure")
    p.set_defaults(func=cmd_optimize)

    p = sub.add_parser("restore",
                       help="undo an optimization (.orig backups)")
    p.add_argument("target", help="deployment directory or app name")
    add_root(p)
    p.add_argument("--variant", default="slimstart")
    p.set_defaults(func=cmd_restore)

    pool = sub.add_parser("pool", help="warm-pool operations")
    pool_sub = pool.add_subparsers(dest="pool_command", required=True)
    p = pool_sub.add_parser("serve",
                            help="boot a zygote and serve fork starts")
    p.add_argument("app", nargs="?", default=None,
                   help="benchsuite app name (or use --app-dir)")
    p.add_argument("--app-dir", default=None,
                   help="explicit deployed app directory")
    add_root(p)
    p.add_argument("--report", default=None,
                   help="report artifact for the pre-import hot set")
    p.add_argument("--requests", type=int, default=5)
    p.add_argument("--invocations", type=int, default=1)
    p.add_argument("--seed", type=int, default=100)
    p.add_argument("--shared-base", action="store_true",
                   help="two-tier: put the hot set in a base zygote "
                        "and fork the app zygote from it")
    p.set_defaults(func=cmd_pool_serve)

    def add_fleet_workload(p: argparse.ArgumentParser) -> None:
        p.add_argument("--trace", default=None,
                       help="trace artifact JSON (default: synthetic "
                            "Azure-style trace over --apps)")
        p.add_argument("--apps",
                       default="graph_bfs,sentiment_analysis_r,echo",
                       help="comma-separated app names for the "
                            "synthetic trace / the served fleet")
        p.add_argument("--minutes", type=int, default=30,
                       help="synthetic trace length")
        p.add_argument("--peak-rpm", type=float, default=60.0,
                       help="synthetic trace peak invocations/minute")
        p.add_argument("--seed", type=int, default=0)
        p.add_argument("--budget-mb", type=float, default=512.0,
                       help="shared fleet memory budget "
                            "(<= 0 with --real: unbounded)")
        p.add_argument("--reports-dir", default=None,
                       help="directory of deployed per-app report "
                            "artifacts (<app>.json): hot sets for "
                            "zygotes / the profile-guided policy, and "
                            "what the rewarm tick re-loads")
        p.add_argument("--shared-base", action="store_true",
                       help="two-tier fleet: one shared base zygote "
                            "pre-imports the cross-app hot set; "
                            "per-app zygotes fork from it and the "
                            "budget charges only their incremental "
                            "memory")
        p.add_argument("--base-min-apps", type=int, default=2,
                       help="a module joins the shared base when hot "
                            "for at least this many member apps")
        p.add_argument("--flip-popularity", action="store_true",
                       help="synthetic trace only: reverse the Zipf "
                            "app popularity order mid-trace (the "
                            "canonical drift scenario for --adaptive)")
        p.add_argument("--flip-minute", type=int, default=None,
                       help="minute the popularity flip lands "
                            "(default: half the trace)")

    def add_adaptive_knobs(p: argparse.ArgumentParser) -> None:
        p.add_argument("--adaptive", action="store_true",
                       help="close the loop: sample live profiles in "
                            "the serving path, watch for workload "
                            "drift, and re-optimize + hot-swap defer "
                            "sets in place on confirmed drift "
                            "(see docs/adaptive.md)")
        p.add_argument("--drift-window-s", type=float, default=60.0,
                       help="drift-detector window length in trace/"
                            "wall seconds")
        p.add_argument("--drift-epsilon", type=float, default=0.002,
                       help="aggregate handler-mix change threshold "
                            "(paper Eq. 7; the applied gate is "
                            "noise-calibrated above this floor)")
        p.add_argument("--drift-out", default=None,
                       help="save the drift_report artifact here")

    def add_fleet_sim_profile(p: argparse.ArgumentParser) -> None:
        p.add_argument("--policy", default="profile",
                       choices=["fixed", "idle", "histogram", "profile"],
                       help="keep-alive policy (simulated fleet)")
        p.add_argument("--idle-timeout-s", type=float, default=600.0)
        p.add_argument("--cold-init-ms", type=float, default=400.0)
        p.add_argument("--warm-init-ms", type=float, default=40.0)
        p.add_argument("--invoke-ms", type=float, default=30.0)
        p.add_argument("--rss-mb", type=float, default=128.0)
        p.add_argument("--zygote-rss-mb", type=float, default=96.0)
        p.add_argument("--zygote-private-mb", type=float, default=0.0,
                       help="measured per-app zygote pages above the "
                            "shared base (0: derive from "
                            "--shared-base-mb)")
        p.add_argument("--shared-base-mb", type=float, default=64.0,
                       help="simulated shared base zygote RSS "
                            "(used with --shared-base)")

    def add_obs_knobs(p: argparse.ArgumentParser) -> None:
        p.add_argument("--log-level", default="info",
                       choices=["debug", "info", "warning", "error"],
                       help="structured-log threshold (stderr)")
        p.add_argument("--log-json", action="store_true",
                       help="JSONL structured logs instead of text")
        p.add_argument("--trace-out", default=None,
                       help="enable span tracing; save the "
                            "trace_events artifact here on exit "
                            "(analyze with `repro obs report`)")

    def add_queue_knobs(p: argparse.ArgumentParser,
                        default_depth: int) -> None:
        p.add_argument("--queue-depth", type=int, default=default_depth,
                       help="bounded per-app queue depth "
                            f"(default {default_depth}"
                            + ("; < 0 disables queueing)"
                               if default_depth < 0 else ")"))
        p.add_argument("--max-concurrency", type=int, default=4,
                       help="demand-spawn cap per app (simulated fleet)")
        p.add_argument("--shed-policy", default="reject-new",
                       choices=["reject-new", "drop-oldest"],
                       help="who is dropped when the queue is full")

    fleet = sub.add_parser("fleet", help="multi-app fleet operations")
    fleet_sub = fleet.add_subparsers(dest="fleet_command", required=True)
    p = fleet_sub.add_parser("replay",
                             help="replay a trace through the fleet "
                                  "(simulated, or --real zygotes)")
    add_fleet_workload(p)
    add_fleet_sim_profile(p)
    add_queue_knobs(p, default_depth=-1)
    add_obs_knobs(p)
    add_adaptive_knobs(p)
    p.add_argument("--real", action="store_true",
                   help="replay through a live ZygoteFleet over the "
                        "deployed benchsuite apps (one zygote per app "
                        "under --budget-mb)")
    add_root(p)
    p.add_argument("--limit", type=int, default=None,
                   help="with --real: replay only the first N requests")
    p.add_argument("--out", default=None,
                   help="save the fleet_summary artifact here")
    p.add_argument("--chaos", default=None, metavar="PLAN",
                   help="with --real: inject faults while replaying — "
                        "a saved chaos_plan JSON path, or the literal "
                        "'storm' for the canonical seeded crash storm "
                        "(see docs/chaos.md)")
    p.add_argument("--chaos-seed", type=int, default=0,
                   help="seed for --chaos storm event timing")
    p.add_argument("--chaos-report", default=None,
                   help="save the chaos_report artifact here "
                        "(injections, recoveries, conservation check)")
    p.add_argument("--boot-backoff-s", type=float, default=0.5,
                   help="base delay of the zygote reboot exponential "
                        "backoff (chaos replay)")
    p.add_argument("--breaker-max-failures", type=int, default=3,
                   help="consecutive zygote boot failures before the "
                        "per-app circuit breaker demotes the app to "
                        "cold-path-only")
    p.add_argument("--breaker-cooldown-s", type=float, default=30.0,
                   help="seconds an open breaker waits before the "
                        "half-open reboot probe")
    p.add_argument("--chaos-pace", type=float, default=0.1,
                   help="scale trace arrival gaps into real time for "
                        "the chaos replay (0 = flood; leave headroom "
                        "above --boot-backoff-s so gated reboots get "
                        "retried)")
    p.add_argument("--dispatch-timeout-s", type=float, default=15.0,
                   help="per-dispatch zygote protocol timeout for the "
                        "chaos replay: a wedged handler sheds with "
                        "reason 'timeout' after this long")
    p.set_defaults(func=cmd_fleet_replay)

    p = fleet_sub.add_parser(
        "serve",
        help="long-running daemon: bounded queues, rewarm timer, "
             "SIGTERM graceful drain",
        description="Own a fleet (simulated with --sim, real zygotes "
                    "otherwise) and serve invocations continuously: "
                    "replayed from a trace, or fed as JSONL on stdin "
                    "with --stdin.  Bounded per-app queues shed "
                    "overload; every rewarm tick re-loads deployed "
                    "report artifacts into the warm fleet; SIGTERM "
                    "drains gracefully and emits a fleet_summary "
                    "artifact (see docs/daemon.md).")
    add_fleet_workload(p)
    add_fleet_sim_profile(p)
    add_queue_knobs(p, default_depth=16)
    add_obs_knobs(p)
    add_adaptive_knobs(p)
    add_root(p)
    p.add_argument("--metrics-port", type=int, default=None,
                   help="expose Prometheus metrics on this port "
                        "(0 = ephemeral; URL lands in the ready line)")
    p.add_argument("--sim", action="store_true",
                   help="simulated fleet (FleetManager) instead of "
                        "real zygotes")
    p.add_argument("--stdin", action="store_true",
                   help="serve a JSONL invocation feed from stdin "
                        "instead of replaying a trace")
    p.add_argument("--pace", type=float, default=0.0,
                   help="scale trace arrival gaps into real time "
                        "(0 = as fast as possible, 1 = real time)")
    p.add_argument("--rewarm-interval-s", type=float, default=0.0,
                   help="rewarm-tick period (0 disables the timer)")
    p.add_argument("--drain-timeout-s", type=float, default=30.0,
                   help="max seconds to wind queues down at shutdown")
    p.add_argument("--summary-out", default=None,
                   help="write the fleet_summary artifact here on "
                        "drain/shutdown")
    p.set_defaults(func=cmd_fleet_serve)

    def add_cluster_workload(p: argparse.ArgumentParser) -> None:
        p.add_argument("--n-apps", type=int, default=16,
                       help="synthetic workload size (apps app00..)")
        p.add_argument("--families", type=int, default=4,
                       help="library families the apps split into "
                            "(siblings share a fat family module)")
        p.add_argument("--seed", type=int, default=0,
                       help="workload + placement seed")
        p.add_argument("--minutes", type=int, default=20,
                       help="synthetic trace length")
        p.add_argument("--peak-rpm", type=float, default=60.0,
                       help="synthetic trace peak invocations/minute")
        p.add_argument("--limit", type=int, default=None,
                       help="replay only the first N arrivals")
        p.add_argument("--node-loss-at", type=int, nargs="*",
                       default=None, metavar="N",
                       help="inject a chaos node_loss fault at these "
                            "0-based route calls (the routed node is "
                            "lost, its apps re-place, the request "
                            "survives)")

    cluster = sub.add_parser(
        "cluster", help="multi-node cluster: sharing-aware placement, "
                        "socket-fed node agents, a global router")
    cluster_sub = cluster.add_subparsers(dest="cluster_command",
                                         required=True)

    p = cluster_sub.add_parser(
        "replay",
        help="cluster-scale simulation: N nodes, one router "
             "(--compare: all placement strategies)",
        description="Drive the synthetic cluster workload through N "
                    "simulated nodes under per-node memory budgets. "
                    "--compare replays the same trace under every "
                    "placement strategy (sharing / hash / random) at "
                    "equal budgets — the sharing-aware placement must "
                    "beat plain hashing on cold-start ratio.  --check "
                    "exits 1 if the request-conservation invariant "
                    "breaks on any node or globally "
                    "(see docs/cluster.md).")
    add_cluster_workload(p)
    add_obs_knobs(p)
    p.add_argument("--nodes", type=int, default=4,
                   help="simulated node count (default 4)")
    p.add_argument("--node-budget-mb", type=float, default=512.0,
                   help="per-node memory budget")
    p.add_argument("--strategy", default="sharing",
                   choices=["sharing", "hash", "random"],
                   help="placement strategy (ignored by --compare, "
                        "which runs all; still picks the --out payload)")
    p.add_argument("--compare", action="store_true",
                   help="replay under every strategy and print the "
                        "comparison table")
    p.add_argument("--out", default=None,
                   help="save the cluster_summary artifact here "
                        "(the --strategy run)")
    p.add_argument("--check", action="store_true",
                   help="exit 1 if conservation breaks")
    p.set_defaults(func=cmd_cluster_replay)

    p = cluster_sub.add_parser(
        "serve",
        help="one node agent: a fleet daemon behind a frame-protocol "
             "socket",
        description="Serve one cluster node: the fleet daemon's full "
                    "surface (bounded queues, rewarm timer, graceful "
                    "drain) behind a length-prefixed-frame TCP socket "
                    "accepting many concurrent feeders.  Prints a "
                    "ready line with the bound port on stdout; a "
                    "shutdown frame or SIGTERM drains and prints the "
                    "node's fleet_summary (see docs/cluster.md).")
    add_cluster_workload(p)
    add_queue_knobs(p, default_depth=16)
    add_obs_knobs(p)
    add_root(p)
    p.add_argument("--node-id", default="node0",
                   help="this node's name in the cluster")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="listen port (0 = ephemeral; the ready line "
                        "carries the bound port)")
    p.add_argument("--sim", action="store_true",
                   help="simulated fleet over the synthetic cluster "
                        "workload instead of real zygotes")
    p.add_argument("--apps", default=None,
                   help="comma-separated apps this node deploys "
                        "(--sim default: every workload app; real "
                        "mode: required benchsuite app names)")
    p.add_argument("--budget-mb", type=float, default=512.0,
                   help="node memory budget (<= 0 with real zygotes: "
                        "unbounded)")
    p.add_argument("--reports-dir", default=None,
                   help="deployed per-app report artifacts "
                        "(<app>.json) for zygote hot sets / rewarm")
    p.add_argument("--shared-base", action="store_true",
                   help="real mode: two-tier fleet with a shared base "
                        "zygote")
    p.add_argument("--base-min-apps", type=int, default=2,
                   help="real mode: modules hot for at least this "
                        "many apps join the shared base")
    p.add_argument("--rewarm-interval-s", type=float, default=0.0,
                   help="rewarm-tick period (0 disables the timer)")
    p.add_argument("--drain-timeout-s", type=float, default=30.0,
                   help="max seconds to wind queues down at shutdown")
    p.add_argument("--drain-on-disconnect", action="store_true",
                   help="treat 'last feeder disconnected' as the "
                        "drain signal (stdin-EOF semantics over "
                        "sockets)")
    p.add_argument("--summary-out", default=None,
                   help="write the node's fleet_summary artifact here "
                        "on drain/shutdown")
    p.set_defaults(func=cmd_cluster_serve)

    p = cluster_sub.add_parser(
        "route",
        help="the global router: place apps on live node agents and "
             "feed them a trace",
        description="Connect to running node agents (cluster serve), "
                    "learn who deploys what, place every app "
                    "(sharing-aware by default), stream the trace "
                    "over the sockets, then drain the nodes and merge "
                    "their ledgers + latency sample pools into one "
                    "cluster_summary artifact.  --check exits 1 if "
                    "request conservation breaks anywhere "
                    "(see docs/cluster.md).")
    add_cluster_workload(p)
    add_obs_knobs(p)
    p.add_argument("--nodes", required=True,
                   help="comma-separated node agents: "
                        "id=host:port[,id=host:port...]")
    p.add_argument("--strategy", default="sharing",
                   choices=["sharing", "hash", "random"],
                   help="placement strategy")
    p.add_argument("--trace", default=None,
                   help="trace artifact to replay (default: the "
                        "synthetic cluster workload's trace)")
    p.add_argument("--reports-dir", default=None,
                   help="with --trace: per-app report artifacts for "
                        "sharing-aware hot sets")
    p.add_argument("--pace", type=float, default=0.0,
                   help="scale trace arrival gaps into real time "
                        "(0 = as fast as possible)")
    p.add_argument("--out", default=None,
                   help="save the cluster_summary artifact here")
    p.add_argument("--check", action="store_true",
                   help="exit 1 if conservation breaks")
    p.add_argument("--ha", action="store_true",
                   help="replicated router: lease-elected leader + "
                        "warm standby tailing the ledger (see "
                        "docs/cluster.md)")
    p.add_argument("--standby-id", default="router-b",
                   help="with --ha: the standby router's id")
    p.add_argument("--lease-ttl-s", type=float, default=5.0,
                   help="with --ha: leader lease TTL (renewed every "
                        "ttl/3 on the routing path)")
    p.add_argument("--kill-leader-at", type=int, nargs="*",
                   default=None, metavar="N",
                   help="with --ha: inject a chaos router_loss at "
                        "these 0-based election-site hits — the "
                        "leader halts abruptly and the standby must "
                        "take over mid-replay")
    p.add_argument("--handoff-stall-at", type=int, nargs="*",
                   default=None, metavar="N",
                   help="inject a chaos handoff_stall at these "
                        "0-based handoff-site hits (the app degrades "
                        "to a cold re-place)")
    p.add_argument("--leave-node", default=None, metavar="ID",
                   help="planned decommission: drain this node with "
                        "warm-state handoff once --leave-at requests "
                        "have routed")
    p.add_argument("--leave-at", type=int, default=0, metavar="N",
                   help="route this many requests before the planned "
                        "leave (default 0)")
    p.add_argument("--cold-leave", action="store_true",
                   help="skip the warm handoff exchange on the "
                        "planned leave (cold re-place baseline)")
    add_retry_flags(p)
    p.set_defaults(func=cmd_cluster_route)

    obs = sub.add_parser("obs", help="observability: trace analysis "
                                     "and the live fleet console")
    obs_sub = obs.add_subparsers(dest="obs_command", required=True)
    p = obs_sub.add_parser(
        "report",
        help="cold-start anatomy from a trace_events artifact",
        description="Break a trace_events capture (fleet replay/serve "
                    "--trace-out) into per-phase p50/p99/self-time "
                    "shares, list the slowest imports, and optionally "
                    "emit folded stacks for flamegraph.pl.")
    p.add_argument("path", help="trace_events artifact JSON")
    p.add_argument("--top", type=int, default=10,
                   help="slowest-import rows to show (default 10)")
    p.add_argument("--flame", default=None,
                   help="write folded stacks here (one "
                        "'root;child;leaf value' line per frame)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable breakdown instead of tables")
    p.set_defaults(func=cmd_obs_report)

    p = obs_sub.add_parser(
        "top",
        help="live per-app fleet table from a /metrics endpoint",
        description="Scrape a serving daemon's Prometheus endpoint "
                    "(fleet serve --metrics-port) or a metrics "
                    "textfile and render a refreshing per-app table: "
                    "requests, cold ratio, shed rate, queue depth, "
                    "queue-wait p99, base swaps, rewarm ticks.")
    p.add_argument("--url", default=None,
                   help="full metrics URL (e.g. "
                        "http://127.0.0.1:9464/metrics)")
    p.add_argument("--port", type=int, default=None,
                   help="shorthand for http://127.0.0.1:PORT/metrics")
    p.add_argument("--file", default=None,
                   help="metrics textfile path instead of a URL")
    p.add_argument("--interval", type=float, default=2.0,
                   help="seconds between scrapes (default 2)")
    p.add_argument("--iterations", type=int, default=0,
                   help="stop after N renders (0 = until ^C)")
    p.add_argument("--no-clear", action="store_true",
                   help="append renders instead of clearing the screen")
    p.set_defaults(func=cmd_obs_top)

    drift = sub.add_parser(
        "drift", help="adaptive-loop drift reports")
    drift_sub = drift.add_subparsers(dest="drift_command", required=True)
    p = drift_sub.add_parser(
        "status",
        help="render a saved drift_report artifact",
        description="Show what an adaptive run saw and did: the "
                    "noise-calibrated detector config, every closed "
                    "window's component scores (handler-mix change, "
                    "defer-set misses, new hot modules) and whether "
                    "it fired, plus the re-optimization actions and "
                    "any swallowed errors.  Produced by fleet "
                    "replay/serve --adaptive --drift-out.")
    p.add_argument("path", help="drift_report artifact JSON")
    p.add_argument("--json", action="store_true",
                   help="dump the versioned payload as JSON")
    p.set_defaults(func=cmd_drift_status)

    p = sub.add_parser("ci-check",
                       help="re-profile and compare against the deployed "
                            "report (exit 1 on drift)")
    p.add_argument("app")
    p.add_argument("--deployed", required=True,
                   help="the report artifact the deployment was "
                        "optimized from")
    add_root(p)
    add_profiling(p)
    p.add_argument("--out", default=None,
                   help="save the fresh report artifact here (for CI "
                        "artifact upload)")
    p.add_argument("--retries", type=int, default=0,
                   help="re-profile a mismatch up to N times; fail "
                        "only on persistent drift (default 0)")
    p.set_defaults(func=cmd_ci_check)

    p = sub.add_parser("docs",
                       help="(re)generate docs/cli.md from this parser "
                            "(--check: exit 1 on drift)")
    p.add_argument("--out", default="docs/cli.md",
                   help="where the CLI reference lives")
    p.add_argument("--check", action="store_true",
                   help="verify the committed file matches the "
                        "generated one instead of writing it")
    p.set_defaults(func=cmd_docs)

    return ap


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if getattr(args, "func", None) is cmd_pool_serve \
            and not (args.app or args.app_dir):
        print("pool serve: need an app name or --app-dir",
              file=sys.stderr)
        return 2
    try:
        return args.func(args)
    except ArtifactError as exc:
        print(f"artifact error: {exc}", file=sys.stderr)
        return 2
    except FileNotFoundError as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    except KeyboardInterrupt:
        return 130
    except Exception as exc:
        # exit code 1 is reserved for ci-check divergence; any other
        # failure (broken profiling run, dead zygote, ...) must not be
        # mistaken for workload drift by a CI wrapper
        import traceback
        traceback.print_exc()
        print(f"error: {exc}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
