"""AST-based deferred-import transformation (paper §IV-B).

Given a source file and a list of *defer targets* (dotted package names
flagged by the profiler, e.g. ``nltk.sem``), the transformer:

1. finds module-level import statements whose imported modules fall
   inside a defer target's subtree;
2. performs a scope-aware safety analysis of every name the statement
   binds;
3. comments out the global import and re-inserts the statement at the
   top of each function that uses the binding ("first usage point" per
   scope — lazy, and paid only by the code paths that need it);
4. for bindings with *no* in-file usage (pure re-exports, the
   ``igraph.__init__`` pattern), appends a PEP 562 ``__getattr__`` shim
   so external attribute access still works;
5. refuses (and reports) any import whose binding is used at module
   level, in a class body, in a lambda, or rebound via ``global`` —
   deferring those could change behaviour.

The rewrite is *line surgery* guided by the AST rather than
``ast.unparse`` so untouched code keeps its formatting, comments and
line numbers (important for diffability in CI/CD integration).
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Optional, Sequence

SHIM_BEGIN = "# --- SLIMSTART deferred-import shim (auto-generated) ---"
COMMENT_TAG = "# SLIMSTART: deferred"


# --------------------------------------------------------------------------
# Import statement model
# --------------------------------------------------------------------------

@dataclass
class _Binding:
    name: str  # name bound in the module namespace
    import_module: str  # module whose import must be triggered
    attr: Optional[str]  # attribute to fetch from import_module (from-imports)
    root: Optional[str]  # for "import a.b": binding is root module "a"


@dataclass
class _ImportStmt:
    node: ast.stmt
    lineno: int  # 1-based first line
    end_lineno: int
    bindings: list[_Binding]
    text: str  # deferred replacement statement (one per binding set)


def _resolve_relative(module: Optional[str], level: int,
                      module_name: Optional[str]) -> Optional[str]:
    """Resolve a relative ``from . import x`` given the file's dotted name.

    ``module_name`` should name the *module* the file defines
    (e.g. ``fakelib_igraph`` for ``fakelib_igraph/__init__.py``,
    ``fakelib_igraph.clustering`` for ``clustering.py``).  Packages
    (``__init__``) resolve level 1 to themselves.
    """
    if level == 0:
        return module
    if module_name is None:
        return None
    parts = module_name.split(".")
    # For a plain module, level 1 refers to its parent package.
    # _resolve is called with is_pkg flag via module_name convention:
    # callers pass the *package* path for __init__ files.
    base = parts[: len(parts) - (level - 1)] if level > 1 else parts
    if not base:
        return None
    prefix = ".".join(base)
    return f"{prefix}.{module}" if module else prefix


def _in_subtree(module: str, targets: Sequence[str]) -> bool:
    return any(module == t or module.startswith(t + ".") for t in targets)


def _collect_imports(tree: ast.Module, targets: Sequence[str],
                     module_name: Optional[str],
                     is_package: bool) -> list[_ImportStmt]:
    """Module-level import statements matching a defer target.

    Conditional imports (inside module-level ``if``/``try``) are *not*
    collected — deferring them could change feature-detection behaviour.
    """
    out: list[_ImportStmt] = []
    pkg_name = module_name if is_package else (
        module_name.rsplit(".", 1)[0] if module_name and "." in module_name
        else None)
    for node in tree.body:
        if isinstance(node, ast.Import):
            bindings = []
            for alias in node.names:
                mod = alias.name
                if not _in_subtree(mod, targets):
                    continue
                if alias.asname:
                    bindings.append(_Binding(alias.asname, mod, None, None))
                else:
                    root = mod.split(".", 1)[0]
                    bindings.append(_Binding(root, mod, None, root))
            if bindings:
                out.append(_ImportStmt(node, node.lineno, node.end_lineno,
                                       bindings, ast.unparse(node)))
        elif isinstance(node, ast.ImportFrom):
            if any(a.name == "*" for a in node.names):
                continue  # star imports are never safe to defer
            resolved = _resolve_relative(
                node.module, node.level,
                module_name if is_package else pkg_name)
            if resolved is None:
                continue
            # A from-import matches if the source module is in a target
            # subtree, or if it imports a *submodule* that is.
            direct = _in_subtree(resolved, targets)
            bindings = []
            for alias in node.names:
                sub = f"{resolved}.{alias.name}"
                if direct:
                    bindings.append(
                        _Binding(alias.asname or alias.name, resolved,
                                 alias.name, None))
                elif _in_subtree(sub, targets):
                    # ``from pkg import heavy_submodule``
                    bindings.append(
                        _Binding(alias.asname or alias.name, sub, None, None))
            if bindings and len(bindings) == len(node.names):
                out.append(_ImportStmt(node, node.lineno, node.end_lineno,
                                       bindings, ast.unparse(node)))
            elif bindings:
                # Mixed statement (some names deferred, some not): rewrite
                # as two statements is possible; keep simple & safe — defer
                # only if every alias matched (report otherwise).
                pass
    return out


# --------------------------------------------------------------------------
# Usage / safety analysis
# --------------------------------------------------------------------------

class _UsageVisitor(ast.NodeVisitor):
    """Scope-aware usage analysis for a set of module-level bindings."""

    def __init__(self, names: set[str]):
        self.names = names
        self.func_stack: list[ast.AST] = []
        self.lambda_depth = 0
        self.class_depth = 0
        # name -> list of top-level-function nodes that read it
        self.func_uses: dict[str, set[ast.AST]] = {n: set() for n in names}
        # name -> True if used at module level / class body / lambda
        self.unsafe: dict[str, bool] = {n: False for n in names}
        # functions that rebind a name locally (no import needed there)
        self.local_rebinds: dict[ast.AST, set[str]] = {}

    # -- scope tracking
    def _enter_func(self, node):
        self.func_stack.append(node)
        # Parameters / assignments shadow globals inside this function.
        self.local_rebinds.setdefault(node, set())
        for arg in list(getattr(node.args, "args", [])) + \
                list(getattr(node.args, "posonlyargs", [])) + \
                list(getattr(node.args, "kwonlyargs", [])):
            if arg.arg in self.names:
                self.local_rebinds[node].add(arg.arg)
        self.generic_visit(node)
        self.func_stack.pop()

    def visit_FunctionDef(self, node):
        # Decorators & default args evaluate in the enclosing scope.
        for dec in node.decorator_list:
            self.visit(dec)
        for d in list(node.args.defaults) + [d for d in node.args.kw_defaults
                                             if d is not None]:
            self.visit(d)
        self._enter_func(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        self.lambda_depth += 1
        self.generic_visit(node)
        self.lambda_depth -= 1

    def visit_ClassDef(self, node):
        for dec in node.decorator_list:
            self.visit(dec)
        for base in node.bases + [kw.value for kw in node.keywords]:
            self.visit(base)
        self.class_depth += 1
        for stmt in node.body:
            self.visit(stmt)
        self.class_depth -= 1

    # -- usages
    def visit_Name(self, node):
        name = node.id
        if name not in self.names:
            return
        if isinstance(node.ctx, ast.Load):
            if self.lambda_depth > 0:
                # Lambdas evaluate later; we cannot insert imports there.
                if not self.func_stack:
                    self.unsafe[name] = True
                else:
                    self.func_uses[name].add(self.func_stack[0])
            elif not self.func_stack:
                self.unsafe[name] = True  # module/class-level read
            elif self.class_depth > 0 and self._class_inside_func():
                self.func_uses[name].add(self.func_stack[0])
            else:
                self.func_uses[name].add(self.func_stack[0])
        else:  # Store / Del
            if self.func_stack:
                self.local_rebinds.setdefault(
                    self.func_stack[-1], set()).add(name)
            else:
                self.unsafe[name] = True  # module-level rebind

    def _class_inside_func(self) -> bool:
        return bool(self.func_stack)

    def visit_Global(self, node):
        for name in node.names:
            if name in self.names:
                self.unsafe[name] = True

    def visit_Import(self, node):  # ignore the import statements themselves
        pass

    def visit_ImportFrom(self, node):
        pass


# --------------------------------------------------------------------------
# Result / driver
# --------------------------------------------------------------------------

@dataclass
class OptimizeResult:
    deferred: list[str] = field(default_factory=list)  # statements deferred
    shimmed: list[str] = field(default_factory=list)  # names served by shim
    skipped: list[str] = field(default_factory=list)  # unsafe, left alone
    n_insertions: int = 0
    changed: bool = False


def optimize_tree(source: str, targets: Sequence[str],
                  module_name: Optional[str] = None,
                  is_package: bool = False) -> tuple[str, OptimizeResult]:
    """Pure-function core: returns (new_source, result)."""
    res = OptimizeResult()
    if not targets:
        return source, res
    tree = ast.parse(source)
    imports = _collect_imports(tree, targets, module_name, is_package)
    if not imports:
        return source, res

    names: set[str] = set()
    for imp in imports:
        names.update(b.name for b in imp.bindings)
    visitor = _UsageVisitor(names)
    visitor.visit(tree)

    lines = source.splitlines(keepends=True)
    # Edits: (line_index, kind, payload) applied bottom-up.
    comment_ranges: list[tuple[int, int]] = []
    insertions: dict[int, list[str]] = {}  # 0-based line -> stmts to insert
    shim_entries: dict[str, tuple[tuple[str, ...], Optional[str], Optional[str]]] = {}

    for imp in imports:
        unsafe = [b.name for b in imp.bindings if visitor.unsafe[b.name]]
        if unsafe:
            res.skipped.append(
                f"{imp.text} (module-level use of {', '.join(unsafe)})")
            continue
        res.deferred.append(imp.text)
        comment_ranges.append((imp.lineno - 1, imp.end_lineno - 1))
        for b in imp.bindings:
            users = visitor.func_uses[b.name]
            # Functions that locally rebind the name never read the global.
            users = {
                f for f in users
                if b.name not in visitor.local_rebinds.get(f, set())
            }
            # Every deferred binding also gets a PEP 562 shim entry: the
            # module's namespace is public API (``pkg.sub`` attribute
            # access from outside must keep working even though the
            # global import is gone).  The shim only fires when the name
            # is absent from globals, so it costs nothing on the paths
            # that imported it via the in-function deferred import.
            prev = shim_entries.get(b.name)
            mods = (prev[0] if prev else ()) + (b.import_module,)
            shim_entries[b.name] = (mods, b.attr, b.root)
            if not users:
                res.shimmed.append(b.name)
                continue
            stmt = _binding_stmt(b)
            for fn in users:
                line0 = _body_insert_line(fn)
                indent = _body_indent(fn, lines)
                insertions.setdefault(line0, []).append(
                    f"{indent}{stmt}  {COMMENT_TAG}\n")
                res.n_insertions += 1

    if not res.deferred:
        return source, res

    # Apply edits bottom-up so line numbers stay valid.
    for line0 in sorted(insertions, reverse=True):
        lines[line0:line0] = insertions[line0]
    for lo, hi in sorted(comment_ranges, reverse=True):
        for i in range(lo, hi + 1):
            stripped = lines[i]
            prefix_len = len(stripped) - len(stripped.lstrip())
            lines[i] = (stripped[:prefix_len] + "# " +
                        stripped[prefix_len:].rstrip("\n") +
                        f"  {COMMENT_TAG}\n")

    new_source = "".join(lines)
    if shim_entries:
        new_source += _render_shim(shim_entries)
    res.changed = True
    return new_source, res


def _binding_stmt(b: _Binding) -> str:
    if b.attr is not None:
        return f"from {b.import_module} import {b.attr} as {b.name}"
    if b.root is not None:  # plain ``import a.b`` binding root ``a``
        return f"import {b.import_module}"
    return f"import {b.import_module} as {b.name}"


def _body_insert_line(fn: ast.AST) -> int:
    """0-based line index of the first *non-docstring* body statement."""
    body = fn.body
    first = body[0]
    if (isinstance(first, ast.Expr)
            and isinstance(first.value, ast.Constant)
            and isinstance(first.value.value, str) and len(body) > 1):
        first = body[1]
    return first.lineno - 1


def _body_indent(fn: ast.AST, lines: list[str]) -> str:
    line = lines[_body_insert_line(fn)]
    return line[: len(line) - len(line.lstrip())]


def _render_shim(entries: dict[str, tuple[tuple[str, ...], Optional[str],
                                          Optional[str]]]) -> str:
    rows = ",\n".join(
        f"    {name!r}: ({mods!r}, {attr!r}, {root!r})"
        for name, (mods, attr, root) in sorted(entries.items())
    )
    return f"""

{SHIM_BEGIN}
_SLIMSTART_DEFERRED = {{
{rows},
}}


def __getattr__(_name):
    _spec = _SLIMSTART_DEFERRED.get(_name)
    if _spec is None:
        raise AttributeError(_name)
    import importlib as _il
    import sys as _sys
    for _m in _spec[0]:
        _mod = _il.import_module(_m)
    if _spec[1] is not None:
        try:
            # __dict__ lookup: must not re-enter this __getattr__ when the
            # attribute is really a submodule of *this* package.
            _val = _mod.__dict__[_spec[1]]
        except KeyError:
            _val = _il.import_module(_spec[0][-1] + "." + _spec[1])
    elif _spec[2] is not None:
        _val = _sys.modules[_spec[2]]
    else:
        _val = _mod
    globals()[_name] = _val
    return _val
# --- end SLIMSTART shim ---
"""


def optimize_source(source: str, targets: Sequence[str],
                    module_name: Optional[str] = None,
                    is_package: bool = False
                    ) -> tuple[str, OptimizeResult]:
    """Alias for :func:`optimize_tree` (public API name)."""
    return optimize_tree(source, targets, module_name, is_package)


def optimize_file(path: str, targets: Sequence[str],
                  module_name: Optional[str] = None,
                  backup: bool = True) -> OptimizeResult:
    """Rewrite ``path`` in place (writing ``path + '.orig'`` first)."""
    with open(path) as fh:
        source = fh.read()
    is_package = os.path.basename(path) == "__init__.py"
    new_source, res = optimize_tree(source, targets, module_name, is_package)
    if res.changed:
        if backup and not os.path.exists(path + ".orig"):
            with open(path + ".orig", "w") as fh:
                fh.write(source)
        with open(path, "w") as fh:
            fh.write(new_source)
    return res


def restore_file(path: str) -> bool:
    """Undo :func:`optimize_file` using the ``.orig`` backup."""
    orig = path + ".orig"
    if not os.path.exists(orig):
        return False
    with open(orig) as fh:
        source = fh.read()
    with open(path, "w") as fh:
        fh.write(source)
    os.remove(orig)
    return True
