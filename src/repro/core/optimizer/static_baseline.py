"""FaaSLight-style static-reachability baseline (paper §II-B, §V-d).

The comparison point the paper evaluates against: a purely static
analysis that

1. parses every source file (application + vendored libraries) into an
   import graph,
2. marks an import edge *live* iff the binding it creates is referenced
   anywhere in the importing module (over-approximating: any handler,
   any code path — static analysis cannot know the workload),
3. computes the set of modules reachable from the application entry
   module over live edges,
4. eliminates (defers) only imports of modules proven unreachable.

Workload-dependent libraries — used by *some* rarely-invoked handler —
are statically reachable and therefore kept, which is exactly the
false-positive class SLIMSTART's dynamic profiling eliminates
(paper Observation 2).  The baseline reuses the same AST rewriter as
SLIMSTART so the measured difference is purely *which* imports each
approach can prove removable.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class _ModuleInfo:
    name: str
    path: str
    # binding name -> absolute module it triggers
    import_bindings: dict[str, str] = field(default_factory=dict)
    # absolute modules imported regardless of binding use (side-effect
    # position: ``from x import y`` always executes x)
    hard_deps: set[str] = field(default_factory=set)
    used_names: set[str] = field(default_factory=set)
    exported_names: set[str] = field(default_factory=set)  # __all__


def _module_name_for(path: str, root: str) -> Optional[str]:
    rel = os.path.relpath(path, root)
    if not rel.endswith(".py"):
        return None
    rel = rel[:-3]
    parts = rel.split(os.sep)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) if parts else None


class StaticReachability:
    """Static import-graph reachability over a source tree."""

    def __init__(self, roots: list[str]) -> None:
        """``roots`` are directories scanned for ``.py`` files (the app dir
        and its vendored library dirs)."""
        self.roots = [os.path.abspath(r) for r in roots]
        self.modules: dict[str, _ModuleInfo] = {}
        self._scan()

    # ------------------------------------------------------------------ scan
    def _scan(self) -> None:
        for root in self.roots:
            for dirpath, _dirnames, filenames in os.walk(root):
                for fn in filenames:
                    if not fn.endswith(".py") or fn.endswith(".orig"):
                        continue
                    path = os.path.join(dirpath, fn)
                    name = _module_name_for(path, root)
                    if name:
                        self.modules[name] = self._parse(name, path)
        # Post-pass: ``from pkg import x`` binds the submodule pkg.x when
        # that module exists in-tree, otherwise the attribute's package.
        for info in self.modules.values():
            for binding, mod in list(info.import_bindings.items()):
                if "." in mod and mod not in self.modules:
                    parent = mod.rsplit(".", 1)[0]
                    if parent in self.modules:
                        info.import_bindings[binding] = parent

    def _parse(self, name: str, path: str) -> _ModuleInfo:
        info = _ModuleInfo(name=name, path=path)
        with open(path) as fh:
            try:
                tree = ast.parse(fh.read())
            except SyntaxError:
                return info
        is_pkg = os.path.basename(path) == "__init__.py"
        pkg = name if is_pkg else (name.rsplit(".", 1)[0]
                                   if "." in name else "")
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    binding = alias.asname or alias.name.split(".", 1)[0]
                    info.import_bindings[binding] = alias.name
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                if node.level > 0:
                    base = pkg.split(".") if pkg else []
                    base = base[: len(base) - (node.level - 1)] \
                        if node.level > 1 else base
                    mod = ".".join(base + ([mod] if mod else []))
                if not mod:
                    continue
                info.hard_deps.add(mod)
                for alias in node.names:
                    if alias.name == "*":
                        info.used_names.add("*")
                        continue
                    binding = alias.asname or alias.name
                    # ``from pkg import sub`` may bind a submodule; resolved
                    # against the full module table in the _scan post-pass.
                    info.import_bindings[binding] = f"{mod}.{alias.name}"
            elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
                info.used_names.add(node.id)
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id == "__all__":
                        if isinstance(node.value, (ast.List, ast.Tuple)):
                            for elt in node.value.elts:
                                if isinstance(elt, ast.Constant) and \
                                        isinstance(elt.value, str):
                                    info.exported_names.add(elt.value)
        return info

    def add_module(self, path: str, name: str) -> None:
        """Register an out-of-root source file (e.g. the app's
        ``handler.py``) under an explicit module name."""
        self.modules[name] = self._parse(name, path)
        for info in self.modules.values():
            for binding, mod in list(info.import_bindings.items()):
                if "." in mod and mod not in self.modules:
                    parent = mod.rsplit(".", 1)[0]
                    if parent in self.modules:
                        info.import_bindings[binding] = parent

    # ----------------------------------------------------------- reachability
    def _live_deps(self, info: _ModuleInfo) -> set[str]:
        """Modules this module keeps alive under static analysis."""
        live: set[str] = set(info.hard_deps)
        star = "*" in info.used_names
        for binding, mod in info.import_bindings.items():
            # Static analysis must keep a binding if it is referenced
            # anywhere in the file OR re-exported (__all__) OR the file
            # star-imports (anything could be used downstream).
            if star or binding in info.used_names \
                    or binding in info.exported_names:
                live.add(mod)
        return live

    def reachable_from(self, entry: str) -> set[str]:
        """Set of in-tree modules statically reachable from ``entry``."""
        seen: set[str] = set()
        stack = [entry]
        while stack:
            cur = stack.pop()
            if cur in seen:
                continue
            seen.add(cur)
            info = self.modules.get(cur)
            if info is None:
                # Maybe a package whose __init__ exists under another key;
                # also walk parent packages (importing a.b imports a).
                continue
            deps = self._live_deps(info)
            for dep in deps:
                # Importing a.b.c imports a and a.b as well.
                parts = dep.split(".")
                for i in range(1, len(parts) + 1):
                    prefix = ".".join(parts[:i])
                    if prefix in self.modules and prefix not in seen:
                        stack.append(prefix)
        return seen

    def unreachable_imports(self, entry: str) -> dict[str, list[str]]:
        """Per-module list of defer targets static analysis can prove.

        Returns {module_name: [unreachable dotted targets]} — the input
        the shared AST rewriter consumes for the STAT baseline.
        """
        reachable = self.reachable_from(entry)
        out: dict[str, list[str]] = {}
        for name in reachable:
            info = self.modules.get(name)
            if info is None:
                continue
            star = "*" in info.used_names
            dead: list[str] = []
            for binding, mod in info.import_bindings.items():
                if star:
                    continue
                if binding in info.used_names or \
                        binding in info.exported_names:
                    continue
                if mod in self.modules or \
                        mod.split(".", 1)[0] in self.modules:
                    dead.append(mod)
            if dead:
                out[name] = sorted(set(dead))
        return out
