"""Runtime lazy-module proxy.

Fallback actuator for cases where the AST transform cannot prove a
deferred import safe (module-level usage of the binding): the global
import is replaced by ``name = lazy_import("pkg.mod")`` which defers the
real import to the first *attribute access* instead of the first call.
This is the importlib.util.LazyLoader idea with two additions we need:

* the proxy is reentrant-safe (imports under a lock, then swaps itself
  out of the caller's namespace is NOT attempted — attribute access
  keeps going through the proxy, which is measurably cheap);
* ``is_loaded`` / ``loaded_modules`` introspection so the profiler can
  report which deferred imports actually fired under a workload.
"""

from __future__ import annotations

import importlib
import threading
from typing import Any, Optional

_REGISTRY: dict[str, "LazyModule"] = {}
_REGISTRY_LOCK = threading.Lock()


class LazyModule:
    """Import-on-first-attribute-access module proxy."""

    __slots__ = ("_lazy_name", "_lazy_module", "_lazy_lock")

    def __init__(self, name: str) -> None:
        object.__setattr__(self, "_lazy_name", name)
        object.__setattr__(self, "_lazy_module", None)
        object.__setattr__(self, "_lazy_lock", threading.Lock())

    def _lazy_load(self):
        mod = object.__getattribute__(self, "_lazy_module")
        if mod is None:
            lock = object.__getattribute__(self, "_lazy_lock")
            with lock:
                mod = object.__getattribute__(self, "_lazy_module")
                if mod is None:
                    name = object.__getattribute__(self, "_lazy_name")
                    mod = importlib.import_module(name)
                    object.__setattr__(self, "_lazy_module", mod)
        return mod

    def __getattr__(self, item: str) -> Any:
        return getattr(self._lazy_load(), item)

    def __setattr__(self, key: str, value: Any) -> None:
        setattr(self._lazy_load(), key, value)

    def __dir__(self):
        return dir(self._lazy_load())

    def __repr__(self) -> str:
        name = object.__getattribute__(self, "_lazy_name")
        loaded = object.__getattribute__(self, "_lazy_module") is not None
        state = "loaded" if loaded else "deferred"
        return f"<LazyModule {name!r} ({state})>"

    @property
    def is_loaded(self) -> bool:  # pragma: no cover - trivial
        return object.__getattribute__(self, "_lazy_module") is not None


def lazy_import(name: str) -> LazyModule:
    """Return a (cached) lazy proxy for ``name``."""
    with _REGISTRY_LOCK:
        proxy = _REGISTRY.get(name)
        if proxy is None:
            proxy = LazyModule(name)
            _REGISTRY[name] = proxy
        return proxy


def loaded_modules() -> dict[str, bool]:
    """Which lazily-declared modules have actually been imported."""
    with _REGISTRY_LOCK:
        return {
            name: object.__getattribute__(p, "_lazy_module") is not None
            for name, p in _REGISTRY.items()
        }


def reset_registry() -> None:
    """Test helper: forget all proxies (does not unimport modules)."""
    with _REGISTRY_LOCK:
        _REGISTRY.clear()
