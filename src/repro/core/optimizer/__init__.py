"""SLIMSTART automated code optimizer (paper §IV-B).

``ast_transform`` rewrites flagged *global* imports into *deferred*
imports at their first usage points (function entry of every function
that uses the binding), preserving functional correctness:

* bindings that are only re-exported (no in-file usage) are served by a
  generated PEP 562 module ``__getattr__`` shim, keeping the public API;
* bindings used at module level / in lambdas / class bodies are left
  untouched (unsafe to defer) and reported;
* everything else: the global import is commented out and the statement
  is re-inserted at the top of each using function.

``static_baseline`` implements the FaaSLight-style comparison point:
static reachability over the module import graph, removing only imports
that no code path can reach — workload-blind by construction.

``lazy_import`` provides the runtime proxy fallback, and ``lazy_params``
/ ``lazy_compile`` are the Level-B actuators (deferred weight
materialization and deferred entry-point compilation) — see DESIGN.md §2.
"""

from repro.core.optimizer.ast_transform import (
    OptimizeResult,
    optimize_source,
    optimize_file,
    optimize_tree,
)
from repro.core.optimizer.lazy_import import lazy_import, LazyModule
from repro.core.optimizer.static_baseline import StaticReachability

__all__ = [
    "OptimizeResult",
    "optimize_source",
    "optimize_file",
    "optimize_tree",
    "lazy_import",
    "LazyModule",
    "StaticReachability",
]
