"""SLIMSTART — the paper's primary contribution.

Profile-guided optimization of serverless cold starts:

* ``repro.core.profiler`` — dynamic profiler: import-time hierarchy
  (Eq. 1-3), sampling call-path profiler + CCT, utilization metric
  (Eq. 4), inefficiency detection, reports, async collection.
* ``repro.core.optimizer`` — automated code optimizer: AST
  deferred-import transform, PEP 562 re-export shim, lazy-module proxy,
  FaaSLight-style static baseline, and the Level-B actuators
  (lazy weight materialization / deferred compilation).
* ``repro.core.adaptive`` — Eq. 5-7 workload-shift monitor and the
  CI/CD control loop.
"""

from repro.core import adaptive, optimizer, profiler  # noqa: F401

__all__ = ["profiler", "optimizer", "adaptive"]
