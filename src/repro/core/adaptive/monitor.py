"""Data-driven adaptive profiling trigger (paper §IV-C, Eq. 5-7).

Tracks per-handler invocation counts in fixed windows of width Δt.  At
each window boundary it computes

    p_i(t)  = N_i(t) / Σ_j N_j(t)                  (Eq. 5)
    Δp_i(t) = p_i(t) - p_i(t - Δt)                 (Eq. 6)

and signals a re-profile when

    Σ_i |Δp_i(t)| > ε                              (Eq. 7)

Handlers appearing or disappearing between windows contribute their full
probability mass to the aggregate change (|p - 0|), so new entry points
trigger profiling naturally.  The clock is injectable for tests and for
trace replay (benchmarks/bench_adaptive.py replays an Azure-style trace
through this exact code).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class MonitorConfig:
    window_s: float = 12 * 3600.0  # paper uses 12-hour windows
    epsilon: float = 0.002  # paper's ε
    min_invocations: int = 1  # ignore empty windows


@dataclass
class WindowStats:
    t_end: float
    probabilities: dict[str, float]
    total_invocations: int
    aggregate_change: float
    triggered: bool


class WorkloadMonitor:
    """Streaming Eq. 5-7 evaluator."""

    def __init__(self, config: MonitorConfig | None = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.config = config or MonitorConfig()
        self.clock = clock
        self._counts: dict[str, int] = {}
        self._window_start = clock()
        self._prev_probs: Optional[dict[str, float]] = None
        self.history: list[WindowStats] = []
        self.triggers = 0

    # --------------------------------------------------------------- record
    def record(self, handler: str, n: int = 1) -> Optional[WindowStats]:
        """Record ``n`` invocations of ``handler``.  If the current window
        has elapsed, close it and return its stats (with the trigger
        decision); otherwise return None."""
        now = self.clock()
        closed = None
        if now - self._window_start >= self.config.window_s:
            closed = self._close_window(now)
        self._counts[handler] = self._counts.get(handler, 0) + n
        return closed

    def flush(self) -> Optional[WindowStats]:
        """Force-close the current window (end of trace / shutdown)."""
        return self._close_window(self.clock())

    # ---------------------------------------------------------------- window
    def _close_window(self, now: float) -> Optional[WindowStats]:
        counts, self._counts = self._counts, {}
        self._window_start = now
        total = sum(counts.values())
        if total < self.config.min_invocations:
            return None
        probs = {h: c / total for h, c in counts.items()}  # Eq. 5
        if self._prev_probs is None:
            change = 0.0
            triggered = False
        else:
            keys = set(probs) | set(self._prev_probs)
            change = sum(
                abs(probs.get(k, 0.0) - self._prev_probs.get(k, 0.0))  # Eq. 6
                for k in keys
            )
            triggered = change > self.config.epsilon  # Eq. 7
        self._prev_probs = probs
        stats = WindowStats(
            t_end=now,
            probabilities=probs,
            total_invocations=total,
            aggregate_change=change,
            triggered=triggered,
        )
        self.history.append(stats)
        if triggered:
            self.triggers += 1
        return stats
