"""In-production closed loop: live profiling -> drift detection ->
in-process re-optimization (paper §IV-C taken all the way to runtime).

The offline pipeline (``repro.api.stages``) profiles cold instances,
analyzes the shards, and writes an :class:`OptimizationReport` artifact
that the serving fleet loads at boot.  This module closes the loop the
paper's "adaptive monitoring" section sketches: the *serving path
itself* keeps profiling a sampled subset of dispatches, watches the
workload for drift, and — when drift is confirmed — regenerates the
report in-process and hot-swaps defer sets + shared base through the
existing ``rewarm``/``rebase`` machinery, with zero sheds and no
restart.

Three pieces:

:class:`LiveProfiler`
    Folds per-exec profile payloads (``ImportTimer`` records + a
    serialized :class:`CCT`, produced inside forkserver children and
    shipped back on the exec reply) into rolling per-app state, and
    regenerates an :class:`OptimizationReport` with *exactly* the
    offline ``analyze_sink`` recipe — mean-merged timers, merged +
    escalated CCT, mean e2e — so the live and offline pipelines are
    differentially testable against each other.

:class:`DriftDetector`
    Extends :class:`WorkloadMonitor` (Eq. 5-7) with two more drift
    signals — defer-set hit-rate and new-hot-module appearance — and a
    *noise-calibrated* trigger.  The paper's ε=0.002 assumes windows of
    millions of invocations; at serving-window volumes multinomial
    sampling noise alone exceeds it, so the effective gate is

        eps_eff = max(epsilon, noise_guard * sqrt(k*(1/n_prev + 1/n_cur)))

    where ``k`` is the number of distinct handlers and ``n_*`` the
    window totals.  ``sqrt(k*(1/n_prev + 1/n_cur))`` is a Cauchy-Schwarz
    upper bound on E[Σ|Δp̂|] under a stationary workload, so with the
    default guard the detector provably (and property-testedly) does
    not fire on stationary traffic, while a real popularity flip moves
    Σ|Δp| by O(1) and fires immediately.

:class:`AdaptiveLoop`
    Glues the two together behind three injected callbacks —
    ``regenerate_fn`` (build a fresh report for an app),
    ``apply_fn`` (deploy it: ``ZygoteFleet.rewarm`` /
    ``ProfileGuidedPolicy.add_report``), and optional ``swap_fn``
    (``ZygoteFleet.maybe_swap_base``) — so the same loop drives the
    simulated and the real fleet.  Emits ``repro_drift_score`` /
    ``repro_sampler_overhead_pct`` gauges and a versioned
    ``drift_report`` artifact payload.
"""

from __future__ import annotations

import math
import statistics
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.adaptive.monitor import MonitorConfig, WorkloadMonitor
from repro.core.profiler.cct import CCT
from repro.core.profiler.report import OptimizationReport
from repro.core.profiler.utilization import (
    AnalyzerConfig,
    ModuleMapper,
    UtilizationAnalyzer,
)


# ---------------------------------------------------------------------------
# Live profiler
# ---------------------------------------------------------------------------

@dataclass
class LiveProfileConfig:
    """Knobs for in-serving-path profiling.

    The sampler interval is coarser than the offline profiler's 2 ms
    (we are riding production requests, not dedicated profiling
    instances); ``sample_every`` throttles which dispatches carry the
    profiler at all, which is where the <=3 % overhead budget
    (tools/perf_smoke.py gate) comes from.
    """

    interval_s: float = 0.010  # sampler tick inside the child
    timer: str = "prof"  # CPU-time sampling, like the offline profiler
    max_depth: int = 128
    sample_every: int = 8  # profile 1 of every N dispatches per app
    max_shards: int = 64  # rolling init-record shards kept per app
    max_e2e: int = 256  # rolling e2e samples kept per app

    def exec_config(self) -> dict:
        """The dict shipped to the forkserver child on a profiled exec."""
        return {"interval_s": self.interval_s, "timer": self.timer,
                "max_depth": self.max_depth}


def baseline_records_from_report(report: OptimizationReport) -> dict:
    """Synthetic init-record shard for a deployed report's hot set.

    Modules preloaded into the zygote are in ``sys.modules`` before the
    fork, so child-side ``ImportTimer`` records never see them — a live
    regeneration from child shards alone would conclude the hot
    libraries cost nothing and defer them.  This folds the deployed
    report's per-*library* init times back in as one extra shard
    (top-level stats only: a library stat's ``init_s`` already covers
    its subtree, so including sub-package prefixes would double-count
    under ``ImportTimer.package_times``).
    """
    out: dict[str, dict] = {}
    for s in report.stats:
        if not s.is_library or s.init_s <= 0:
            continue
        out[s.name] = {
            "filename": s.file or "<baseline>",
            "self_s": s.init_s,
            "cumulative_s": s.init_s,
            "parent": None,
            "importer_file": None,
            "importer_lineno": 0,
        }
    return out


@dataclass
class _AppProfileState:
    cct: CCT = field(default_factory=CCT)
    shards: list = field(default_factory=list)  # init_records dicts
    e2e_s: list = field(default_factory=list)
    baseline: Optional[dict] = None  # synthetic shard, see above
    n_payloads: int = 0
    n_signals: int = 0
    overhead_s: float = 0.0  # profiler cost inside profiled execs
    exec_s: float = 0.0  # total wall of profiled execs


class LiveProfiler:
    """Rolling per-app profile state fed by exec replies.

    Thread-safe: the real backend's worker threads call
    :meth:`observe` concurrently.
    """

    def __init__(self, config: LiveProfileConfig | None = None) -> None:
        self.config = config or LiveProfileConfig()
        self._lock = threading.Lock()
        self._apps: dict[str, _AppProfileState] = {}

    def _state(self, app: str) -> _AppProfileState:
        st = self._apps.get(app)
        if st is None:
            st = self._apps[app] = _AppProfileState()
        return st

    # ----------------------------------------------------------------- feed
    def observe(self, app: str, payload: dict) -> None:
        """Fold one exec's ``live_profile`` reply payload into the
        rolling state.  Payload shape mirrors the offline profile shard
        (``benchsuite.runner``): ``init_records``, ``cct``,
        ``e2e_cold_s``, ``n_signals``, ``overhead_s``, ``exec_s``."""
        cfg = self.config
        with self._lock:
            st = self._state(app)
            st.n_payloads += 1
            st.n_signals += int(payload.get("n_signals", 0))
            st.overhead_s += float(payload.get("overhead_s", 0.0))
            st.exec_s += float(payload.get("exec_s", 0.0))
            recs = payload.get("init_records")
            if recs:
                st.shards.append(recs)
                if len(st.shards) > cfg.max_shards:
                    del st.shards[:len(st.shards) - cfg.max_shards]
            if payload.get("cct"):
                st.cct.merge(CCT.from_dict(payload["cct"]))
            if payload.get("e2e_cold_s") is not None:
                st.e2e_s.append(float(payload["e2e_cold_s"]))
                if len(st.e2e_s) > cfg.max_e2e:
                    del st.e2e_s[:len(st.e2e_s) - cfg.max_e2e]

    def set_baseline(self, app: str,
                     report: OptimizationReport) -> None:
        """Seed an app with its deployed report (see
        :func:`baseline_records_from_report`)."""
        with self._lock:
            self._state(app).baseline = \
                baseline_records_from_report(report)

    # ------------------------------------------------------------- analysis
    def has_data(self, app: str) -> bool:
        with self._lock:
            st = self._apps.get(app)
            return bool(st and (st.shards or st.e2e_s))

    def apps(self) -> list[str]:
        with self._lock:
            return sorted(self._apps)

    def regenerate(self, app: str, libs_dir: str,
                   config: AnalyzerConfig | None = None
                   ) -> Optional[OptimizationReport]:
        """Re-run Analyze on the live state — the offline
        ``analyze_sink`` recipe verbatim (mean-merged timers, merged +
        escalated CCT copy, mean e2e), so the differential test in
        ``tests/test_adaptive_loop.py`` can hold the two pipelines to
        the same answer on the same records."""
        from repro.api.stages import _merge_import_timers
        with self._lock:
            st = self._apps.get(app)
            if st is None or not st.e2e_s or not st.shards:
                return None
            shards = list(st.shards)
            if st.baseline:
                shards.append(st.baseline)
            cct = CCT()
            cct.merge(st.cct)
            e2e = statistics.fmean(st.e2e_s)
        timer = _merge_import_timers(shards)
        cct.escalate()
        mapper = ModuleMapper((libs_dir,))
        analyzer = UtilizationAnalyzer(timer, cct, mapper, e2e_s=e2e,
                                       config=config)
        return OptimizationReport.from_analyzer(app, analyzer)

    # -------------------------------------------------------------- metrics
    def overhead_pct(self, app: Optional[str] = None) -> float:
        """Profiler cost as % of profiled-exec wall time (the paper's
        <=10 % in-band budget; our CI gate holds end-to-end p50 to 3 %)."""
        with self._lock:
            states = ([self._apps[app]] if app in self._apps
                      else list(self._apps.values()) if app is None
                      else [])
            over = sum(s.overhead_s for s in states)
            total = sum(s.exec_s for s in states)
        return 100.0 * over / total if total > 0 else 0.0

    def snapshot(self) -> dict:
        with self._lock:
            return {
                app: {
                    "profiled_execs": st.n_payloads,
                    "shards": len(st.shards),
                    "n_signals": st.n_signals,
                    "baseline": st.baseline is not None,
                }
                for app, st in sorted(self._apps.items())
            }


# ---------------------------------------------------------------------------
# Drift detection
# ---------------------------------------------------------------------------

@dataclass
class DriftConfig:
    """Noise-calibrated drift gate over serving-scale windows."""

    window_s: float = 60.0
    epsilon: float = 0.002  # paper's ε — the floor of the gate
    # eps_eff multiplier over the stationary-noise bound
    # sqrt(k*(1/n_prev+1/n_cur)); 4.0 puts a stationary window's
    # Σ|Δp̂| past the gate with probability < exp(-5k) (McDiarmid),
    # which the hypothesis sweep in tests/test_pool_properties.py
    # hammers on
    noise_guard: float = 4.0
    min_invocations: int = 20  # ignore near-empty windows
    min_hit_rate: float = 0.5  # defer-set hit-rate floor
    min_profiled: int = 3  # hit-rate needs this many profiled execs
    new_module_threshold: int = 3  # distinct new hot modules per window
    cooldown_windows: int = 1  # windows to sit out after a fire

    def monitor_config(self) -> MonitorConfig:
        return MonitorConfig(window_s=self.window_s,
                             epsilon=self.epsilon,
                             min_invocations=self.min_invocations)


@dataclass
class DriftWindow:
    """One closed window's drift verdict (rides in drift_report)."""

    t_end: float
    total_invocations: int
    aggregate_change: float  # Σ|Δp| (Eq. 7 left-hand side)
    eps_eff: float  # noise-calibrated gate actually applied
    mix_score: float  # aggregate_change / eps_eff
    hit_rate: Optional[float]  # None when too few profiled execs
    miss_score: float
    new_modules: list[str]
    new_module_score: float
    score: float  # max of the components; >1 means drift
    fired: bool
    suppressed: bool  # score>1 but inside the post-fire cooldown

    def to_payload(self) -> dict:
        return {
            "t_end": round(self.t_end, 3),
            "invocations": self.total_invocations,
            "mix_change": round(self.aggregate_change, 5),
            "eps_eff": round(self.eps_eff, 5),
            "mix_score": round(self.mix_score, 3),
            "hit_rate": (round(self.hit_rate, 4)
                         if self.hit_rate is not None else None),
            "miss_score": round(self.miss_score, 3),
            "new_modules": list(self.new_modules),
            "new_module_score": round(self.new_module_score, 3),
            "score": round(self.score, 3),
            "fired": self.fired,
            "suppressed": self.suppressed,
        }


class DriftDetector(WorkloadMonitor):
    """Eq. 5-7 plus defer-set hit-rate and new-hot-module signals.

    Keys are ``app/handler`` so both per-app popularity flips and
    per-handler mix shifts inside one app move the same Σ|Δp|.  The
    clock is injectable *and* overridable per record (``t=``), so trace
    replay drives the detector in trace time.
    """

    def __init__(self, config: DriftConfig | None = None,
                 clock: Callable[[], float] = time.monotonic) -> None:
        self.drift_config = config or DriftConfig()
        self._base_clock = clock
        self._t_override: Optional[float] = None
        super().__init__(self.drift_config.monitor_config(),
                         clock=self._now)
        self.windows: list[DriftWindow] = []
        self.fires = 0
        self._cooldown = 0
        self._primed = False
        self._win_hits = 0
        self._win_misses = 0
        self._win_new_modules: set[str] = set()

    def _now(self) -> float:
        return (self._t_override if self._t_override is not None
                else self._base_clock())

    # ----------------------------------------------------------------- feed
    def observe(self, app: str, handler: Optional[str] = None,
                n: int = 1, t: Optional[float] = None
                ) -> Optional[DriftWindow]:
        """Record one arrival; returns the closed :class:`DriftWindow`
        when this arrival rolled the window over."""
        self._t_override = t
        try:
            if not self._primed:
                # align the first window to the stream's own clock: a
                # trace replay observes in *trace* time while the
                # monitor base class stamped construction wall time
                self._window_start = self._now()
                self._primed = True
            before = len(self.windows)
            self.record(f"{app}/{handler or '_'}", n)
        finally:
            self._t_override = None
        return self.windows[-1] if len(self.windows) > before else None

    def note_hit(self, hit: bool) -> None:
        """One profiled exec's defer-set verdict: ``hit`` means no
        deferred module was imported at runtime."""
        if hit:
            self._win_hits += 1
        else:
            self._win_misses += 1

    def note_new_modules(self, names) -> None:
        """Top-level modules seen initializing in a child that are in
        neither the deployed hot set nor the defer set."""
        self._win_new_modules.update(names)

    def flush(self, t: Optional[float] = None) -> Optional[DriftWindow]:
        """Force-close the trailing window (end of trace / drain)."""
        self._t_override = t
        try:
            before = len(self.windows)
            super().flush()
        finally:
            self._t_override = None
        return self.windows[-1] if len(self.windows) > before else None

    # --------------------------------------------------------------- window
    def _close_window(self, now: float):
        hits, misses = self._win_hits, self._win_misses
        new_mods = sorted(self._win_new_modules)
        self._win_hits = self._win_misses = 0
        self._win_new_modules = set()
        stats = super()._close_window(now)
        if stats is None:
            return None
        cfg = self.drift_config

        # mix-shift component, against the noise-calibrated gate
        if len(self.history) >= 2:
            prev = self.history[-2]
            k = max(len(set(stats.probabilities)
                        | set(prev.probabilities)), 1)
            noise = math.sqrt(k * (1.0 / max(prev.total_invocations, 1)
                                   + 1.0 / max(stats.total_invocations,
                                               1)))
            eps_eff = max(cfg.epsilon, cfg.noise_guard * noise)
            mix_score = stats.aggregate_change / eps_eff
        else:
            eps_eff = cfg.epsilon
            mix_score = 0.0  # first window: nothing to diff against

        # defer-set hit-rate component (profiled subset only)
        hit_rate: Optional[float] = None
        miss_score = 0.0
        if hits + misses >= cfg.min_profiled:
            hit_rate = hits / (hits + misses)
            if cfg.min_hit_rate < 1.0:
                miss_score = (1.0 - hit_rate) / (1.0 - cfg.min_hit_rate)

        # new-hot-module component
        new_score = (len(new_mods) / cfg.new_module_threshold
                     if cfg.new_module_threshold > 0 else 0.0)

        score = max(mix_score, miss_score, new_score)
        suppressed = False
        fired = False
        if score > 1.0 and len(self.history) >= 2:
            if self._cooldown > 0:
                suppressed = True
            else:
                fired = True
                self._cooldown = cfg.cooldown_windows
                self.fires += 1
        if not fired and self._cooldown > 0:
            self._cooldown -= 1

        win = DriftWindow(
            t_end=now, total_invocations=stats.total_invocations,
            aggregate_change=stats.aggregate_change, eps_eff=eps_eff,
            mix_score=mix_score, hit_rate=hit_rate,
            miss_score=miss_score, new_modules=new_mods,
            new_module_score=new_score, score=score, fired=fired,
            suppressed=suppressed)
        self.windows.append(win)
        return stats

    @property
    def last_score(self) -> float:
        return self.windows[-1].score if self.windows else 0.0


# ---------------------------------------------------------------------------
# The loop
# ---------------------------------------------------------------------------

@dataclass
class AdaptiveConfig:
    profile: LiveProfileConfig = field(default_factory=LiveProfileConfig)
    drift: DriftConfig = field(default_factory=DriftConfig)
    max_actions: int = 50  # bounded re-optimization action log
    max_errors: int = 50


class AdaptiveLoop:
    """Observe -> detect -> regenerate -> hot-swap, behind callbacks.

    ``regenerate_fn(app, profiler)`` returns a fresh
    :class:`OptimizationReport` (or None to skip the app);
    ``apply_fn(report)`` deploys it into the serving path
    (``ZygoteFleet.rewarm`` / ``ProfileGuidedPolicy.add_report`` — both
    shed nothing); ``swap_fn()`` optionally recomputes the shared base
    afterwards (``ZygoteFleet.maybe_swap_base``); ``hot_sets_fn(app)``
    returns ``(hot_modules, defer_targets)`` top-level sets for the
    deployed report, feeding the hit-rate / new-module signals.

    ``fault_hook`` is the chaos seam (site ``"profiler"``): an injected
    ``profiler_stall`` aborts one re-optimization round — serving is
    never touched, the error lands in the drift report.
    """

    def __init__(self, *,
                 regenerate_fn: Callable[..., Optional[OptimizationReport]],
                 apply_fn: Callable[[OptimizationReport], object],
                 swap_fn: Optional[Callable[[], object]] = None,
                 hot_sets_fn: Optional[Callable[[str], tuple]] = None,
                 config: AdaptiveConfig | None = None,
                 clock: Callable[[], float] = time.monotonic,
                 fault_hook=None) -> None:
        self.config = config or AdaptiveConfig()
        self.regenerate_fn = regenerate_fn
        self.apply_fn = apply_fn
        self.swap_fn = swap_fn
        self.hot_sets_fn = hot_sets_fn
        self.fault_hook = fault_hook
        self.profiler = LiveProfiler(self.config.profile)
        self.detector = DriftDetector(self.config.drift, clock=clock)
        self.actions: list[dict] = []
        self.errors: list[str] = []
        self.applied = 0
        self.swaps = 0
        self._lock = threading.RLock()
        self._dispatches: dict[str, int] = {}
        self._window_apps: set[str] = set()
        self._last_window_apps: set[str] = set()

    # -------------------------------------------------------------- serving
    def observe_request(self, app: str, handler: Optional[str] = None,
                        t: Optional[float] = None) -> Optional[dict]:
        """Record one admission.  Returns the child-side profiler
        config when *this* dispatch should carry the live profiler
        (every ``sample_every``-th per app), else None.  Closing a
        window — and any re-optimization it fires — happens inline,
        which in the single-threaded replay path is exactly what makes
        the swap shed-free: it runs between requests."""
        with self._lock:
            self._window_apps.add(app)
            closed = self.detector.observe(app, handler, t=t)
            if closed is not None:
                self._last_window_apps = self._window_apps
                self._window_apps = {app}
                if closed.fired:
                    self._reoptimize(closed)
                self._export_gauges()
            n = self._dispatches.get(app, 0)
            self._dispatches[app] = n + 1
            if n % max(self.config.profile.sample_every, 1) == 0:
                return self.config.profile.exec_config()
            return None

    def observe_exec(self, app: str, metrics: dict) -> None:
        """Fold a dispatch reply's ``live_profile`` payload (if any)
        into the profiler and the drift signals.  Pops the payload so
        it never leaks into latency summaries."""
        payload = metrics.pop("live_profile", None) \
            if isinstance(metrics, dict) else None
        if not payload:
            return
        self.profiler.observe(app, payload)
        if self.hot_sets_fn is None:
            return
        with self._lock:
            try:
                hot, defer = self.hot_sets_fn(app)
            except Exception:
                return
            tops = {name.split(".", 1)[0]
                    for name in (payload.get("init_records") or {})}
            hot = {h.split(".", 1)[0] for h in hot}
            defer = {d.split(".", 1)[0] for d in defer}
            # a child importing a deferred module at init means the
            # defer decision cost this request a lazy load: a miss
            self.detector.note_hit(not (tops & defer))
            new = tops - hot - defer - {"handler"}
            if new:
                self.detector.note_new_modules(new)

    def flush(self, t: Optional[float] = None) -> None:
        """Close the trailing window at end of trace / drain."""
        with self._lock:
            closed = self.detector.flush(t=t)
            if closed is not None:
                self._last_window_apps = self._window_apps
                self._window_apps = set()
                if closed.fired:
                    self._reoptimize(closed)
                self._export_gauges()

    # ----------------------------------------------------------- reoptimize
    def _reoptimize(self, window: DriftWindow) -> None:
        """One confirmed-drift round: regenerate + apply per app, then
        swap the shared base.  Never raises — a failed round (including
        an injected ``profiler_stall``) is recorded and skipped; the
        serving path is untouched either way."""
        apps = sorted(self._last_window_apps) or self.profiler.apps()
        entry = {"t": round(window.t_end, 3),
                 "score": round(window.score, 3), "apps": apps,
                 "applied": [], "swapped": False}
        try:
            if self.fault_hook is not None:
                # chaos site "profiler": a profiler_stall lands here
                self.fault_hook("profiler", app="_adaptive")
            for app in apps:
                report = self.regenerate_fn(app, self.profiler)
                if report is None:
                    continue
                self.apply_fn(report)
                self.applied += 1
                entry["applied"].append(
                    {"app": app, "qualifies": report.qualifies,
                     "defer_targets": list(report.defer_targets)})
            if entry["applied"] and self.swap_fn is not None:
                self.swap_fn()
                self.swaps += 1
                entry["swapped"] = True
        except Exception as exc:
            entry["error"] = repr(exc)
            if len(self.errors) >= self.config.max_errors:
                del self.errors[:1]
            self.errors.append(f"t={entry['t']}: {exc!r}")
        if len(self.actions) >= self.config.max_actions:
            del self.actions[:1]
        self.actions.append(entry)

    def _export_gauges(self) -> None:
        from repro.obs.metrics import default_registry
        reg = default_registry()
        reg.gauge("repro_drift_score",
                  "latest window's drift score (>1 means drift)",
                  labels=("app",)).labels(app="_fleet").set(
            self.detector.last_score)
        reg.gauge("repro_sampler_overhead_pct",
                  "live-profiler cost as % of profiled exec wall time",
                  labels=("app",)).labels(app="_fleet").set(
            round(self.profiler.overhead_pct(), 3))

    # ------------------------------------------------------------ reporting
    def summary(self) -> dict:
        """Compact block for the fleet_summary artifact."""
        with self._lock:
            return {
                "windows": len(self.detector.windows),
                "fires": self.detector.fires,
                "applied": self.applied,
                "base_swaps": self.swaps,
                "final_score": round(self.detector.last_score, 3),
                "sampler_overhead_pct":
                    round(self.profiler.overhead_pct(), 3),
                "errors": len(self.errors),
            }

    def drift_report_payload(self, source: str = "live") -> dict:
        """Payload for the versioned ``drift_report`` artifact."""
        cfg = self.config
        with self._lock:
            return {
                "source": source,
                "config": {
                    "window_s": cfg.drift.window_s,
                    "epsilon": cfg.drift.epsilon,
                    "noise_guard": cfg.drift.noise_guard,
                    "min_hit_rate": cfg.drift.min_hit_rate,
                    "new_module_threshold":
                        cfg.drift.new_module_threshold,
                    "cooldown_windows": cfg.drift.cooldown_windows,
                    "sample_every": cfg.profile.sample_every,
                    "interval_s": cfg.profile.interval_s,
                },
                "windows": [w.to_payload()
                            for w in self.detector.windows],
                "fires": self.detector.fires,
                "actions": list(self.actions),
                "final_score": round(self.detector.last_score, 3),
                "sampler_overhead_pct":
                    round(self.profiler.overhead_pct(), 3),
                "apps": self.profiler.snapshot(),
                "errors": list(self.errors),
            }
