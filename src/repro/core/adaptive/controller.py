"""CI/CD-style control loop tying monitor -> profiler -> optimizer.

The paper integrates SLIMSTART into the deployment pipeline: the
adaptive monitor watches live traffic; when Eq. 7 fires, a profiling
phase is scheduled, the analyzer regenerates the optimization report,
and the code optimizer re-applies deferred imports for the *new*
workload (previously deferred imports whose packages became hot are
restored first — the ``.orig`` backups make the transform reversible).

The controller is deliberately synchronous and callback-driven so the
same code runs (a) in unit tests with a fake clock, (b) under the local
serverless harness, and (c) inside the Level-B serving engine where the
"optimizer" callback swaps lazy-materialization policies instead of
rewriting source.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.adaptive.monitor import MonitorConfig, WindowStats, WorkloadMonitor
from repro.core.profiler.report import OptimizationReport


@dataclass
class ControllerConfig:
    monitor: MonitorConfig = field(default_factory=MonitorConfig)
    # Cooldown: don't re-profile more often than this many seconds even if
    # every window triggers (guards against oscillating workloads).
    cooldown_s: float = 0.0
    # Profile this many invocations when a profiling phase is scheduled.
    profile_invocations: int = 200


class SlimStartController:
    """Adaptive profile->optimize loop.

    Parameters
    ----------
    profile_fn:
        Callable invoked to run a profiling phase; must return an
        :class:`OptimizationReport`.
    optimize_fn:
        Callable applying the report (AST rewrite / lazy policy swap).
    rewarm_fn:
        Optional callable invoked with the fresh report *after* the
        optimizer ran — hooks the warm pool into the adaptive loop so a
        workload shift also re-warms the zygote's pre-import set (pass
        ``ForkServer.rewarm`` or a pool manager's equivalent).  Rewarm
        failures are recorded in ``rewarm_errors`` but never abort the
        phase: a stale-but-running pool beats a dead control loop.
    """

    def __init__(
        self,
        profile_fn: Callable[[], OptimizationReport],
        optimize_fn: Callable[[OptimizationReport], None],
        config: ControllerConfig | None = None,
        clock: Callable[[], float] = time.monotonic,
        rewarm_fn: Optional[Callable[[OptimizationReport], object]] = None,
    ) -> None:
        self.config = config or ControllerConfig()
        self.monitor = WorkloadMonitor(self.config.monitor, clock=clock)
        self.profile_fn = profile_fn
        self.optimize_fn = optimize_fn
        self.rewarm_fn = rewarm_fn
        self.clock = clock
        self._last_profile_t: Optional[float] = None
        self.reports: list[OptimizationReport] = []
        self.profile_phases = 0
        self.rewarms = 0
        self.rewarm_errors: list[str] = []

    # ---------------------------------------------------------------- events
    def on_invocation(self, handler: str, n: int = 1) -> Optional[WindowStats]:
        """Feed one (or ``n``) invocation events; runs the re-profile loop
        when the monitor fires."""
        stats = self.monitor.record(handler, n)
        if stats is not None and stats.triggered and self._cooldown_ok():
            self._run_phase()
        return stats

    def force_profile(self) -> OptimizationReport:
        """Initial deployment profiling phase (before any traffic shift)."""
        return self._run_phase()

    # -------------------------------------------------------------- internals
    def _cooldown_ok(self) -> bool:
        if self._last_profile_t is None or self.config.cooldown_s <= 0:
            return True
        return (self.clock() - self._last_profile_t) >= self.config.cooldown_s

    def _run_phase(self) -> OptimizationReport:
        report = self.profile_fn()
        self.reports.append(report)
        self.optimize_fn(report)
        if self.rewarm_fn is not None:
            try:
                self.rewarm_fn(report)
                self.rewarms += 1
            except Exception as exc:
                self.rewarm_errors.append(repr(exc))
        self._last_profile_t = self.clock()
        self.profile_phases += 1
        return report
