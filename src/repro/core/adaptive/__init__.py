"""Adaptive mechanism for evolving workloads (paper §IV-C)."""

from repro.core.adaptive.monitor import WorkloadMonitor, MonitorConfig
from repro.core.adaptive.controller import SlimStartController, ControllerConfig
from repro.core.adaptive.live import (
    AdaptiveConfig,
    AdaptiveLoop,
    DriftConfig,
    DriftDetector,
    DriftWindow,
    LiveProfileConfig,
    LiveProfiler,
    baseline_records_from_report,
)

__all__ = [
    "WorkloadMonitor",
    "MonitorConfig",
    "SlimStartController",
    "ControllerConfig",
    "AdaptiveConfig",
    "AdaptiveLoop",
    "DriftConfig",
    "DriftDetector",
    "DriftWindow",
    "LiveProfileConfig",
    "LiveProfiler",
    "baseline_records_from_report",
]
