"""Adaptive mechanism for evolving workloads (paper §IV-C)."""

from repro.core.adaptive.monitor import WorkloadMonitor, MonitorConfig
from repro.core.adaptive.controller import SlimStartController, ControllerConfig

__all__ = [
    "WorkloadMonitor",
    "MonitorConfig",
    "SlimStartController",
    "ControllerConfig",
]
