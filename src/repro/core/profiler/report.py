"""Optimization report (paper Tables IV/V).

Serializes the analyzer output into the report the paper shows per
application: a summary table (package, utilization %, init overhead %,
file) plus the import call path for each flagged package, and feeds the
automated code optimizer.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field

from repro.core.profiler.utilization import (
    InefficiencyFinding,
    LibraryStats,
    UtilizationAnalyzer,
)


@dataclass
class OptimizationReport:
    application: str
    e2e_s: float
    total_init_s: float
    qualifies: bool
    stats: list[LibraryStats] = field(default_factory=list)
    findings: list[InefficiencyFinding] = field(default_factory=list)
    defer_targets: list[str] = field(default_factory=list)

    @classmethod
    def from_analyzer(cls, application: str,
                      analyzer: UtilizationAnalyzer) -> "OptimizationReport":
        stats = sorted(analyzer.stats().values(), key=lambda s: -s.init_s)
        return cls(
            application=application,
            e2e_s=analyzer.e2e_s,
            total_init_s=analyzer.timer.total_initialization_s(),
            qualifies=analyzer.qualifies(),
            stats=stats,
            findings=analyzer.findings(),
            defer_targets=[f.package for f in analyzer.defer_targets()],
        )

    # ------------------------------------------------------------ serialize
    def to_dict(self) -> dict:
        return {
            "application": self.application,
            "e2e_s": self.e2e_s,
            "total_init_s": self.total_init_s,
            "qualifies": self.qualifies,
            "stats": [
                {
                    "package": s.name,
                    "utilization": s.utilization,
                    "init_s": s.init_s,
                    "init_share": s.init_share,
                    "runtime_samples": s.runtime_samples,
                    "file": s.file,
                }
                for s in self.stats
            ],
            "findings": [
                {
                    "package": f.package,
                    "kind": f.kind,
                    "utilization": f.utilization,
                    "init_s": f.init_s,
                    "init_share": f.init_share,
                    "file": f.file,
                    "call_path": [
                        {
                            "module": r.name,
                            "importer_file": r.importer_file,
                            "importer_lineno": r.importer_lineno,
                        }
                        for r in f.import_chain
                    ],
                }
                for f in self.findings
            ],
            "defer_targets": self.defer_targets,
        }

    def save(self, path: str) -> None:
        """Deprecated shim: atomically writes the *versioned* artifact
        (see :mod:`repro.api.artifacts`); prefer
        :func:`repro.api.save_report`."""
        warnings.warn(
            "OptimizationReport.save is deprecated; use "
            "repro.api.save_report", DeprecationWarning, stacklevel=2)
        from repro.api.artifacts import save_report
        save_report(self, path)

    @classmethod
    def load(cls, path: str) -> "OptimizationReport":
        """Deprecated shim: loads through the versioned artifact layer
        (legacy v1 files migrate with a warning; schema violations
        raise :class:`repro.api.ArtifactError` naming ``path``);
        prefer :func:`repro.api.load_report`."""
        warnings.warn(
            "OptimizationReport.load is deprecated; use "
            "repro.api.load_report", DeprecationWarning, stacklevel=2)
        from repro.api.artifacts import load_report
        return load_report(path)


def render_report(report: OptimizationReport, top: int = 12) -> str:
    """Human-readable rendering in the shape of paper Tables IV/V."""
    lines: list[str] = []
    add = lines.append
    add("=" * 72)
    add("SLIMSTART Summary")
    add(f"Application: {report.application}")
    add(f"End-to-end: {report.e2e_s * 1e3:.1f} ms   "
        f"Library init: {report.total_init_s * 1e3:.1f} ms "
        f"({100 * report.total_init_s / max(report.e2e_s, 1e-9):.1f}%)   "
        f"qualifies: {report.qualifies}")
    add("-" * 72)
    add(f"{'Package':<32}{'Util.%':>8}{'Init.%':>8}  File")
    flagged = {f.package for f in report.findings}
    for s in report.stats[:top]:
        mark = "+" if s.name in flagged else "-"
        add(f"{mark} {s.name:<30}{100 * s.utilization:>7.2f}"
            f"{100 * s.init_share:>8.2f}  {s.file}")
    if report.findings:
        add("-" * 72)
        add("Call Paths")
        for f in report.findings[:top]:
            add(f"  {f.package} [{f.kind}]")
            for rec in f.import_chain:
                loc = (f"{rec.importer_file}:{rec.importer_lineno}"
                       if rec.importer_file else "<unknown>")
                add(f"    -> {rec.name}  (imported at {loc})")
    add(f"Defer targets: {', '.join(report.defer_targets) or '(none)'}")
    add("=" * 72)
    return "\n".join(lines)
