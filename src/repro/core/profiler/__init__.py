"""SLIMSTART dynamic profiler.

The profiler has two halves, mirroring §IV-A of the paper:

1. Hierarchical breakdown of initialization overhead (``import_timer``):
   a ``sys.meta_path`` hook that times every module's top-level execution
   exactly once, attributes self vs. cumulative time, and aggregates
   module -> package -> library -> total (Eq. 1-3).

2. Sampling-based call-path profiling (``sampler`` + ``cct``): an OS-timer
   driven signal handler captures the interrupted call stack; call paths are
   accumulated into a Calling Context Tree whose sample counts are escalated
   toward the root, separating initialization samples from runtime samples.

``utilization`` combines both halves into the U(L) metric (Eq. 4) and flags
inefficient libraries; ``report`` renders Table IV/V-style reports;
``collector`` batches profile records and ships them asynchronously.
"""

from repro.core.profiler.cct import CCT, CCTNode, Frame
from repro.core.profiler.sampler import CallPathSampler, SamplerConfig
from repro.core.profiler.import_timer import ImportTimer, ModuleInitRecord
from repro.core.profiler.utilization import (
    LibraryStats,
    UtilizationAnalyzer,
    InefficiencyFinding,
)
from repro.core.profiler.report import OptimizationReport, render_report
from repro.core.profiler.collector import AsyncCollector

__all__ = [
    "CCT",
    "CCTNode",
    "Frame",
    "CallPathSampler",
    "SamplerConfig",
    "ImportTimer",
    "ModuleInitRecord",
    "LibraryStats",
    "UtilizationAnalyzer",
    "InefficiencyFinding",
    "OptimizationReport",
    "render_report",
    "AsyncCollector",
]
