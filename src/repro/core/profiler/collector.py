"""Asynchronous profile-record collector (paper TC-1, strategy 3).

Profiling data is buffered locally and batch-transferred to an external
collector off the critical path.  In production the sink would be
DynamoDB/S3 (paper §IV-D); here the sink is a directory of JSONL shards,
which the analysis side (``UtilizationAnalyzer``) merges exactly the way
the paper aggregates samples across invocations.
"""

from __future__ import annotations

import json
import os
import queue
import threading
import time
import uuid
from typing import Any, Optional


class AsyncCollector:
    """Background-thread batch writer.

    ``put(record)`` is O(queue append) on the hot path; a daemon thread
    drains the queue and appends JSON lines to a shard file, rotating when
    ``batch_size`` records have been written.
    """

    def __init__(self, sink_dir: str, batch_size: int = 256,
                 flush_interval_s: float = 0.5) -> None:
        self.sink_dir = sink_dir
        self.batch_size = batch_size
        self.flush_interval_s = flush_interval_s
        os.makedirs(sink_dir, exist_ok=True)
        self._q: "queue.Queue[Optional[dict]]" = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self.dropped = 0
        self.written = 0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="slimstart-collector")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._q.put(None)  # wake the drain loop
        self._thread.join(timeout=10.0)
        self._thread = None

    def __enter__(self) -> "AsyncCollector":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -------------------------------------------------------------- hot path
    def put(self, record: dict[str, Any]) -> None:
        self._q.put(record)

    # ------------------------------------------------------------ background
    def _run(self) -> None:
        batch: list[dict] = []
        last_flush = time.monotonic()
        while True:
            timeout = max(0.01, self.flush_interval_s
                          - (time.monotonic() - last_flush))
            try:
                item = self._q.get(timeout=timeout)
            except queue.Empty:
                item = False  # timeout sentinel
            if item:
                batch.append(item)
            now = time.monotonic()
            done = self._stop.is_set() and self._q.empty() and item in (None, False)
            if (len(batch) >= self.batch_size
                    or (batch and now - last_flush >= self.flush_interval_s)
                    or (batch and done)):
                self._flush(batch)
                batch = []
                last_flush = now
            if done:
                return

    def _flush(self, batch: list[dict]) -> None:
        shard = os.path.join(self.sink_dir,
                             f"profile-{uuid.uuid4().hex[:12]}.jsonl")
        tmp = shard + ".tmp"
        try:
            with open(tmp, "w") as fh:
                for rec in batch:
                    fh.write(json.dumps(rec, separators=(",", ":")) + "\n")
            os.replace(tmp, shard)
            self.written += len(batch)
        except OSError:
            self.dropped += len(batch)


def read_shards(sink_dir: str) -> list[dict]:
    """Analysis-side: read every JSONL shard in the sink directory."""
    out: list[dict] = []
    if not os.path.isdir(sink_dir):
        return out
    for name in sorted(os.listdir(sink_dir)):
        if not name.endswith(".jsonl"):
            continue
        with open(os.path.join(sink_dir, name)) as fh:
            for line in fh:
                line = line.strip()
                if line:
                    out.append(json.loads(line))
    return out
