"""Hierarchical breakdown of initialization overhead (paper §IV-A.1).

A ``sys.meta_path`` hook wraps every module loader so the module's
top-level execution is timed exactly once.  Nested imports are handled
with an execution stack: a child's elapsed time is subtracted from the
parent's *self* time but included in the parent's *cumulative* time,
giving the paper's three-level decomposition

    T_total = Σ_k T_library_k          (Eq. 1)
    T_library = Σ_i T_module_i         (Eq. 2)
    T_package = Σ_j T_module_j         (Eq. 3)

where module self-times are the leaves.  The hook also records *who*
imported each module and from which source line, which is what the
optimization report renders as the Call Path section (Tables IV/V).
"""

from __future__ import annotations

import importlib.abc
import importlib.machinery
import sys
import threading
import time
from dataclasses import dataclass, field
from typing import Iterable, Optional


@dataclass(slots=True)
class ModuleInitRecord:
    """Timing record for one module's top-level execution."""

    name: str  # dotted module name, e.g. "nltk.sem"
    filename: str
    self_s: float = 0.0  # time in this module's own top-level code
    cumulative_s: float = 0.0  # includes nested imports it triggered
    parent: Optional[str] = None  # module whose top-level import pulled us in
    importer_file: Optional[str] = None  # source file of the import statement
    importer_lineno: int = 0

    @property
    def library(self) -> str:
        return self.name.split(".", 1)[0]


@dataclass
class _ExecEntry:
    name: str
    t0: float
    child_s: float = 0.0


class _TimedLoader(importlib.abc.Loader):
    def __init__(self, inner, timer: "ImportTimer", fullname: str,
                 importer: tuple[Optional[str], Optional[str], int]):
        self._inner = inner
        self._timer = timer
        self._fullname = fullname
        self._importer = importer

    def create_module(self, spec):
        create = getattr(self._inner, "create_module", None)
        return create(spec) if create is not None else None

    def exec_module(self, module) -> None:
        timer = self._timer
        tls = timer._tls
        stack: list[_ExecEntry] = getattr(tls, "stack", None) or []
        tls.stack = stack
        parent = stack[-1].name if stack else None
        entry = _ExecEntry(self._fullname, time.perf_counter())
        stack.append(entry)
        try:
            self._inner.exec_module(module)
        finally:
            elapsed = time.perf_counter() - entry.t0
            stack.pop()
            if stack:
                stack[-1].child_s += elapsed
            p_name, imp_file, imp_lineno = self._importer
            timer._record(
                ModuleInitRecord(
                    name=self._fullname,
                    filename=getattr(module, "__file__", None) or "<none>",
                    self_s=max(0.0, elapsed - entry.child_s),
                    cumulative_s=elapsed,
                    parent=parent if parent is not None else p_name,
                    importer_file=imp_file,
                    importer_lineno=imp_lineno,
                )
            )

    def __getattr__(self, item):
        return getattr(self._inner, item)


class ImportTimer(importlib.abc.MetaPathFinder):
    """Meta-path hook that times module initialization.

    Usage::

        with ImportTimer() as timer:
            import heavy_library
        print(timer.total_initialization_s())
        print(timer.library_times())

    Restrict measurement to specific roots (e.g. the app's vendored
    dependencies) with ``only_prefixes=("nltk", "igraph")`` or by filesystem
    location with ``only_under=(path,)``.
    """

    def __init__(self, only_prefixes: Iterable[str] = (),
                 only_under: Iterable[str] = ()) -> None:
        self.records: dict[str, ModuleInitRecord] = {}
        self._only_prefixes = tuple(only_prefixes)
        self._only_under = tuple(only_under)
        self._tls = threading.local()
        self._lock = threading.Lock()
        self._installed = False

    # ------------------------------------------------------------ meta_path
    def find_spec(self, fullname, path, target=None):
        if not self._interested(fullname):
            return None
        for finder in sys.meta_path:
            if finder is self:
                continue
            find = getattr(finder, "find_spec", None)
            if find is None:
                continue
            spec = find(fullname, path, target)
            if spec is not None:
                break
        else:
            return None
        if spec.loader is not None and hasattr(spec.loader, "exec_module"):
            if self._only_under and not self._file_interested(spec.origin):
                return spec
            spec.loader = _TimedLoader(
                spec.loader, self, fullname, self._find_importer()
            )
        return spec

    def _interested(self, fullname: str) -> bool:
        if not self._only_prefixes:
            return True
        top = fullname.split(".", 1)[0]
        return top in self._only_prefixes

    def _file_interested(self, origin: Optional[str]) -> bool:
        if origin is None:
            return False
        return any(origin.startswith(root) for root in self._only_under)

    @staticmethod
    def _find_importer() -> tuple[Optional[str], Optional[str], int]:
        """Walk the stack to the import statement that triggered us."""
        f = sys._getframe(1)
        while f is not None:
            fn = f.f_code.co_filename
            if ("importlib" not in fn and not fn.startswith("<frozen")
                    and "repro/core/profiler" not in fn):
                return None, fn, f.f_lineno
            f = f.f_back
        return None, None, 0

    def _record(self, rec: ModuleInitRecord) -> None:
        with self._lock:
            self.records[rec.name] = rec

    # ------------------------------------------------------------ lifecycle
    def install(self) -> None:
        if not self._installed:
            sys.meta_path.insert(0, self)
            self._installed = True

    def uninstall(self) -> None:
        if self._installed:
            try:
                sys.meta_path.remove(self)
            except ValueError:
                pass
            self._installed = False

    def __enter__(self) -> "ImportTimer":
        self.install()
        return self

    def __exit__(self, *exc) -> None:
        self.uninstall()

    # ---------------------------------------------------------- aggregation
    def total_initialization_s(self) -> float:
        """Eq. 1: Σ over libraries (== Σ module self-times)."""
        return sum(r.self_s for r in self.records.values())

    def library_times(self) -> dict[str, float]:
        """Eq. 2: per top-level library, summed module self-times."""
        out: dict[str, float] = {}
        for r in self.records.values():
            out[r.library] = out.get(r.library, 0.0) + r.self_s
        return out

    def package_times(self) -> dict[str, float]:
        """Eq. 3: per package prefix (every dotted prefix accumulates its
        subtree), e.g. nltk, nltk.sem, nltk.sem.logic."""
        out: dict[str, float] = {}
        for r in self.records.values():
            parts = r.name.split(".")
            for i in range(1, len(parts) + 1):
                prefix = ".".join(parts[:i])
                out[prefix] = out.get(prefix, 0.0) + r.self_s
        return out

    def import_chain(self, name: str, max_depth: int = 32) -> list[ModuleInitRecord]:
        """Chain of importers root -> ``name`` (Call Path in Tables IV/V)."""
        chain: list[ModuleInitRecord] = []
        cur = self.records.get(name)
        while cur is not None and len(chain) < max_depth:
            chain.append(cur)
            cur = self.records.get(cur.parent) if cur.parent else None
        chain.reverse()
        return chain

    # ------------------------------------------------------------ serialize
    def to_dict(self) -> dict:
        return {
            name: {
                "filename": r.filename,
                "self_s": r.self_s,
                "cumulative_s": r.cumulative_s,
                "parent": r.parent,
                "importer_file": r.importer_file,
                "importer_lineno": r.importer_lineno,
            }
            for name, r in self.records.items()
        }

    @classmethod
    def from_dict(cls, d: dict) -> "ImportTimer":
        t = cls()
        for name, rd in d.items():
            t.records[name] = ModuleInitRecord(
                name=name,
                filename=rd["filename"],
                self_s=rd["self_s"],
                cumulative_s=rd["cumulative_s"],
                parent=rd["parent"],
                importer_file=rd["importer_file"],
                importer_lineno=rd["importer_lineno"],
            )
        return t
