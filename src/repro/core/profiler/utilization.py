"""Library utilization metric and inefficiency detection (paper §IV-A.2).

Combines the two profiler halves:

* ``ImportTimer`` gives the hierarchical init-time breakdown (Eq. 1-3),
* the ``CCT`` gives runtime sample counts S(f) per function,

into the utilization metric

    U(L) = Σ_{f∈L} S(f) / Σ_{f∈F} S(f)      (Eq. 4)

computed over *runtime* samples (initialization samples are excluded by
construction — the CCT separates them, paper TC-2 solution 3).

Detection policy (paper "Detecting inefficient library usage"):

* the application qualifies if total library init time exceeds
  ``app_gate`` (default 10 %) of end-to-end time;
* packages are ranked by init time; a package is flagged **unused** when
  it has measurable init overhead but zero runtime samples, and
  **rarely-used** when its utilization is below ``util_threshold``
  (default 2 % of samples).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Optional

from repro.core.profiler.cct import CCT, Frame
from repro.core.profiler.import_timer import ImportTimer, ModuleInitRecord


class ModuleMapper:
    """Map source filenames to dotted module / library names.

    ``roots`` are directories that play the role of ``site-packages``:
    a file ``<root>/nltk/sem/__init__.py`` maps to module ``nltk.sem``
    and library ``nltk``.  Files outside all roots map to None (app code
    or stdlib — still counted in the U(L) denominator via ``app_key``).
    """

    def __init__(self, roots: tuple[str, ...]) -> None:
        self.roots = tuple(os.path.abspath(r) for r in roots)

    def module_of(self, filename: str) -> Optional[str]:
        fn = os.path.abspath(filename) if not filename.startswith("<") else filename
        for root in self.roots:
            if fn.startswith(root + os.sep):
                rel = fn[len(root) + 1:]
                if rel.endswith(".py"):
                    rel = rel[:-3]
                parts = rel.split(os.sep)
                if parts and parts[-1] == "__init__":
                    parts = parts[:-1]
                return ".".join(parts) if parts else None
        return None

    def library_of(self, filename: str) -> Optional[str]:
        mod = self.module_of(filename)
        return mod.split(".", 1)[0] if mod else None


@dataclass(slots=True)
class LibraryStats:
    name: str  # dotted package prefix ("nltk", "nltk.sem", ...)
    utilization: float  # U(name), fraction of runtime samples
    init_s: float  # Eq. 2/3 init time for this prefix subtree
    init_share: float  # init_s / e2e_s
    runtime_samples: int
    file: str  # representative file (the package __init__)

    @property
    def is_library(self) -> bool:
        return "." not in self.name


@dataclass(slots=True)
class InefficiencyFinding:
    package: str
    kind: str  # "unused" | "rarely-used"
    utilization: float
    init_s: float
    init_share: float
    file: str
    import_chain: list[ModuleInitRecord] = field(default_factory=list)


@dataclass
class AnalyzerConfig:
    app_gate: float = 0.10  # total lib init must exceed 10% of e2e
    util_threshold: float = 0.02  # 2% of samples => rarely used
    min_init_share: float = 0.01  # ignore packages cheaper than 1% of e2e


class UtilizationAnalyzer:
    def __init__(
        self,
        import_timer: ImportTimer,
        cct: CCT,
        mapper: ModuleMapper,
        e2e_s: float,
        config: AnalyzerConfig | None = None,
    ) -> None:
        self.timer = import_timer
        self.cct = cct
        self.mapper = mapper
        self.e2e_s = max(e2e_s, 1e-9)
        self.config = config or AnalyzerConfig()
        self._stats: Optional[dict[str, LibraryStats]] = None

    # ------------------------------------------------------------- metrics
    def qualifies(self) -> bool:
        """Application-level gate: is library init >10% of e2e?"""
        return (self.timer.total_initialization_s() / self.e2e_s
                ) > self.config.app_gate

    def _samples_by_prefix(self) -> tuple[dict[str, int], int]:
        """Runtime self-samples per package prefix + app-wide total."""
        per_module = self.cct.runtime_self_samples_by(
            lambda fr: self.mapper.module_of(fr.filename) or "<app>"
        )
        total = sum(per_module.values())
        by_prefix: dict[str, int] = {}
        for mod, n in per_module.items():
            if mod == "<app>":
                continue
            parts = mod.split(".")
            for i in range(1, len(parts) + 1):
                p = ".".join(parts[:i])
                by_prefix[p] = by_prefix.get(p, 0) + n
        return by_prefix, total

    def stats(self) -> dict[str, LibraryStats]:
        """Per-package-prefix stats table (libraries and sub-packages)."""
        if self._stats is not None:
            return self._stats
        pkg_times = self.timer.package_times()
        samples, total = self._samples_by_prefix()
        total = max(total, 1)
        files = {
            r.name: r.filename for r in self.timer.records.values()
        }
        out: dict[str, LibraryStats] = {}
        for pkg, t in pkg_times.items():
            n = samples.get(pkg, 0)
            out[pkg] = LibraryStats(
                name=pkg,
                utilization=n / total,
                init_s=t,
                init_share=t / self.e2e_s,
                runtime_samples=n,
                file=files.get(pkg, "<package>"),
            )
        self._stats = out
        return out

    # ------------------------------------------------------------ findings
    def findings(self) -> list[InefficiencyFinding]:
        """Flag unused / rarely-used packages, ranked by init time."""
        cfg = self.config
        if not self.qualifies():
            return []
        rows = sorted(self.stats().values(), key=lambda s: -s.init_s)
        found: list[InefficiencyFinding] = []
        for s in rows:
            if s.init_share < cfg.min_init_share:
                continue
            if s.runtime_samples == 0:
                kind = "unused"
            elif s.utilization < cfg.util_threshold:
                kind = "rarely-used"
            else:
                continue
            found.append(
                InefficiencyFinding(
                    package=s.name,
                    kind=kind,
                    utilization=s.utilization,
                    init_s=s.init_s,
                    init_share=s.init_share,
                    file=s.file,
                    import_chain=self.timer.import_chain(s.name),
                )
            )
        return found

    def defer_targets(self) -> list[InefficiencyFinding]:
        """Maximal flagged subtrees — what the code optimizer should defer.

        If ``nltk`` itself is flagged, deferring ``nltk.sem`` too would be
        redundant; we keep only findings whose ancestors are not flagged.
        """
        found = self.findings()
        flagged = {f.package for f in found}

        def has_flagged_ancestor(pkg: str) -> bool:
            parts = pkg.split(".")
            return any(".".join(parts[:i]) in flagged
                       for i in range(1, len(parts)))

        return [f for f in found if not has_flagged_ancestor(f.package)]
