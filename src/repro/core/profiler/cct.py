"""Calling Context Tree (CCT).

The CCT captures hierarchical caller->callee relationships observed by the
sampling profiler (paper §IV-A.2, Fig. 7).  Each node is one *calling
context* — a function identified by (file, line, name) reached through a
specific path from the root — so the same function invoked through two
different paths occupies two nodes, which is what lets SLIMSTART
distinguish per-path usage (paper TC-2, Lib-6 case).

Sample counts live on the node where the sample's leaf frame landed
(``self_samples``).  ``escalate()`` propagates counts upward so that
orchestrator-style callers are credited with their callees' activity
(paper TC-2, Lib-1 case); the propagated value is ``inclusive_samples``.

Initialization-phase samples (any frame in the path is module top-level
code or an importlib bootstrap frame) are tracked separately from runtime
samples (paper TC-2, Lib-4 case).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Optional


@dataclass(frozen=True, slots=True)
class Frame:
    """One stack frame: enough identity to attribute a sample."""

    filename: str
    lineno: int
    funcname: str

    def is_module_level(self) -> bool:
        """True for frames executing module top-level code (imports)."""
        return self.funcname == "<module>"

    def is_import_machinery(self) -> bool:
        f = self.filename
        return (
            "importlib" in f
            or f.startswith("<frozen importlib")
            or self.funcname == "_call_with_frames_removed"
        )

    def short(self) -> str:
        return f"{self.filename}:{self.lineno} ({self.funcname})"


def path_is_initialization(path: tuple[Frame, ...]) -> bool:
    """A sample is an *initialization* sample if its call chain passes
    through module top-level execution or the import machinery — i.e. the
    work observed is import-time, not request-time (paper §IV-A.2,
    "distinguishes samples originating from library initialization").

    Real imports always run under importlib bootstrap frames, so the
    machinery check is the precise signal.  Entry scripts and exec-based
    launchers (pytest, WSGI, the Lambda bootstrap) also execute
    ``<module>`` frames *without* machinery above them — those are not
    imports.  As a belt for synthetic paths, a ``<module>`` frame of a
    package ``__init__.py`` below the stack root also counts as
    initialization (that is the paper's "__init__ methods of the
    package" rule).
    """
    if any(fr.is_import_machinery() for fr in path):
        return True
    return any(
        fr.is_module_level() and fr.filename.endswith("__init__.py")
        for fr in path[1:]
    )


@dataclass(slots=True)
class CCTNode:
    frame: Frame
    self_samples: int = 0
    init_samples: int = 0  # subset of self_samples taken during import
    inclusive_samples: int = 0  # filled by escalate()
    inclusive_init_samples: int = 0
    children: dict[Frame, "CCTNode"] = field(default_factory=dict)

    def child(self, frame: Frame) -> "CCTNode":
        node = self.children.get(frame)
        if node is None:
            node = CCTNode(frame)
            self.children[frame] = node
        return node

    def walk(self) -> Iterator["CCTNode"]:
        yield self
        for c in self.children.values():
            yield from c.walk()


_ROOT = Frame("<root>", 0, "<root>")


class CCT:
    """Calling Context Tree accumulating sampled call paths."""

    def __init__(self) -> None:
        self.root = CCTNode(_ROOT)
        self.total_samples = 0
        self.total_init_samples = 0

    # ------------------------------------------------------------------ build
    def add_path(self, path: Iterable[Frame], count: int = 1) -> None:
        """Insert one sampled call path (root -> leaf order)."""
        path = tuple(path)
        if not path:
            return
        is_init = path_is_initialization(path)
        node = self.root
        for fr in path:
            node = node.child(fr)
        node.self_samples += count
        if is_init:
            node.init_samples += count
            self.total_init_samples += count
        self.total_samples += count

    def merge(self, other: "CCT") -> None:
        """Merge another CCT into this one (used when aggregating samples
        across invocations / batch-transferred shards)."""

        def rec(dst: CCTNode, src: CCTNode) -> None:
            dst.self_samples += src.self_samples
            dst.init_samples += src.init_samples
            for fr, schild in src.children.items():
                rec(dst.child(fr), schild)

        rec(self.root, other.root)
        self.total_samples += other.total_samples
        self.total_init_samples += other.total_init_samples

    # -------------------------------------------------------------- escalate
    def escalate(self) -> None:
        """Propagate sample counts from leaves toward the root.

        After this pass every node's ``inclusive_samples`` covers its own
        samples plus all descendants' — the paper's sample-escalation step
        that fixes attribution for cascading dependencies."""

        def rec(node: CCTNode) -> tuple[int, int]:
            inc, inc_init = node.self_samples, node.init_samples
            for c in node.children.values():
                ci, cii = rec(c)
                inc += ci
                inc_init += cii
            node.inclusive_samples = inc
            node.inclusive_init_samples = inc_init
            return inc, inc_init

        rec(self.root)

    # ----------------------------------------------------------------- query
    def leaf_self_samples(self) -> dict[Frame, int]:
        """Aggregate self-sample counts per frame identity (across paths)."""
        out: dict[Frame, int] = {}
        for node in self.root.walk():
            if node.self_samples:
                out[node.frame] = out.get(node.frame, 0) + node.self_samples
        return out

    def runtime_self_samples_by(
        self, key: Callable[[Frame], Optional[str]]
    ) -> dict[str, int]:
        """Sum *runtime* (non-init) self samples grouped by ``key(frame)``.

        Frames for which ``key`` returns None are ignored.  This is the
        quantity S(f) aggregated per library for Eq. 4."""
        out: dict[str, int] = {}
        for node in self.root.walk():
            runtime = node.self_samples - node.init_samples
            if runtime <= 0:
                continue
            k = key(node.frame)
            if k is None:
                continue
            out[k] = out.get(k, 0) + runtime
        return out

    def paths_to(self, pred: Callable[[Frame], bool], limit: int = 5
                 ) -> list[tuple[Frame, ...]]:
        """Return up to ``limit`` distinct call paths whose leaf-most frame
        matches ``pred`` — used for the report's Call Path section."""
        found: list[tuple[Frame, ...]] = []

        def rec(node: CCTNode, path: tuple[Frame, ...]) -> None:
            if len(found) >= limit:
                return
            cur = path + (node.frame,)
            if node.frame is not _ROOT and pred(node.frame):
                found.append(cur[1:])  # drop synthetic root
                return
            for c in node.children.values():
                rec(c, cur)

        rec(self.root, ())
        return found

    # ------------------------------------------------------------- serialize
    def to_dict(self) -> dict:
        def rec(node: CCTNode) -> dict:
            return {
                "f": [node.frame.filename, node.frame.lineno, node.frame.funcname],
                "s": node.self_samples,
                "i": node.init_samples,
                "c": [rec(c) for c in node.children.values()],
            }

        return {
            "total": self.total_samples,
            "total_init": self.total_init_samples,
            "root": rec(self.root),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CCT":
        cct = cls()

        def rec(node: CCTNode, dd: dict) -> None:
            for cd in dd["c"]:
                fr = Frame(cd["f"][0], cd["f"][1], cd["f"][2])
                child = node.child(fr)
                child.self_samples = cd["s"]
                child.init_samples = cd["i"]
                rec(child, cd)

        rec(cct.root, d["root"])
        cct.total_samples = d["total"]
        cct.total_init_samples = d["total_init"]
        return cct

    def dumps(self) -> str:
        return json.dumps(self.to_dict(), separators=(",", ":"))

    @classmethod
    def loads(cls, s: str) -> "CCT":
        return cls.from_dict(json.loads(s))
