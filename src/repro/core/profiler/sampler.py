"""Sampling-based call-path profiler (paper §IV-A.2).

A POSIX interval timer fires a signal at a configurable frequency; the
signal handler receives the interrupted frame, unwinds it into a call
path (file, line, function per frame — exactly the four items the paper
collects), and appends it to an in-memory buffer.  Buffers are drained
into a :class:`repro.core.profiler.cct.CCT` either on demand or by the
asynchronous collector.

Two timer flavours:

* ``ITIMER_PROF``/``SIGPROF`` — fires on consumed CPU time (the paper's
  "statistical sampling" of executed code).  Preferred; immune to
  sleeps/IO.
* ``ITIMER_REAL``/``SIGALRM`` — wall-clock; useful when the workload is
  IO-bound and we still want coverage.

The sampler deliberately does *no* allocation-heavy work in the handler
beyond tuple construction, keeping per-sample cost ~microseconds so the
default 10 ms period stays well under the paper's ≤10 % overhead budget.
"""

from __future__ import annotations

import signal
import threading
from dataclasses import dataclass, field
from types import FrameType
from typing import Optional

from repro.core.profiler.cct import CCT, Frame


@dataclass
class SamplerConfig:
    interval_s: float = 0.010  # 100 Hz default
    timer: str = "prof"  # "prof" (CPU time) or "real" (wall clock)
    max_depth: int = 128
    # Frames whose filename contains one of these substrings are elided
    # from the captured path (profiler infrastructure itself).
    elide_substrings: tuple[str, ...] = ("repro/core/profiler",)


@dataclass
class _Buffer:
    paths: list[tuple[Frame, ...]] = field(default_factory=list)
    n_signals: int = 0


class CallPathSampler:
    """Signal-driven call-path sampler.

    Usage::

        sampler = CallPathSampler(SamplerConfig(interval_s=0.005))
        with sampler:
            workload()
        cct = sampler.build_cct()

    Only usable from the main thread (POSIX signal semantics); the serving
    harness runs handlers on the main thread for exactly this reason, as
    AWS Lambda does.
    """

    def __init__(self, config: SamplerConfig | None = None) -> None:
        self.config = config or SamplerConfig()
        self._buffer = _Buffer()
        self._lock = threading.Lock()
        self._active = False
        self._prev_handler = None
        if self.config.timer == "prof":
            self._signum = signal.SIGPROF
            self._itimer = signal.ITIMER_PROF
        elif self.config.timer == "real":
            self._signum = signal.SIGALRM
            self._itimer = signal.ITIMER_REAL
        else:
            raise ValueError(f"unknown timer {self.config.timer!r}")

    # ----------------------------------------------------------- lifecycle
    def start(self) -> None:
        if self._active:
            return
        self._prev_handler = signal.signal(self._signum, self._on_signal)
        signal.setitimer(self._itimer, self.config.interval_s,
                         self.config.interval_s)
        self._active = True

    def stop(self) -> None:
        if not self._active:
            return
        signal.setitimer(self._itimer, 0.0, 0.0)
        signal.signal(self._signum, self._prev_handler or signal.SIG_DFL)
        self._active = False

    def __enter__(self) -> "CallPathSampler":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- handler
    def _on_signal(self, signum: int, frame: Optional[FrameType]) -> None:
        self._buffer.n_signals += 1
        if frame is None:
            return
        path: list[Frame] = []
        depth = 0
        f: Optional[FrameType] = frame
        elide = self.config.elide_substrings
        while f is not None and depth < self.config.max_depth:
            code = f.f_code
            fn = code.co_filename
            if not any(s in fn for s in elide):
                path.append(Frame(fn, f.f_lineno, code.co_name))
            f = f.f_back
            depth += 1
        if path:
            # Stack was unwound leaf -> root; store root -> leaf.
            path.reverse()
            self._buffer.paths.append(tuple(path))

    # --------------------------------------------------------------- drain
    def drain(self) -> list[tuple[Frame, ...]]:
        """Atomically take the accumulated call paths."""
        with self._lock:
            paths = self._buffer.paths
            self._buffer = _Buffer()
        return paths

    @property
    def n_signals(self) -> int:
        return self._buffer.n_signals

    def build_cct(self, into: CCT | None = None) -> CCT:
        """Drain the buffer into a CCT (new or provided) and escalate."""
        cct = into or CCT()
        for path in self.drain():
            cct.add_path(path)
        cct.escalate()
        return cct
