"""Cold-start components — the Level-B analogue of Python libraries.

A serverless model server's cold start decomposes into named components:
weight groups (embeddings, layer stacks, lm head), modality frontends
(vision projection, audio encoder), per-expert weight slices, and one
compiled executable per entry point.  Each component knows how to
materialize itself and records its init cost — feeding the same
hierarchical breakdown (paper Eq. 1-3) and utilization metric (Eq. 4)
as the Level-A profiler, with the *actuator* swapped from "deferred
import" to deferred materialization / compilation.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax


@dataclasses.dataclass
class Component:
    """One lazily-materializable unit of server state."""
    name: str
    group: str  # "weights" | "frontend" | "experts" | "compile"
    build: Callable[[], Any]
    eager: bool = True  # load at cold start (vs on first use)
    value: Any = None
    ready: bool = False
    init_time: float = 0.0
    uses: int = 0

    def get(self):
        if not self.ready:
            t0 = time.perf_counter()
            self.value = self.build()
            jax.block_until_ready(jax.tree.leaves(self.value)) \
                if jax.tree.leaves(self.value) else None
            self.init_time += time.perf_counter() - t0
            self.ready = True
        self.uses += 1
        return self.value

    def drop(self):
        self.value = None
        self.ready = False


@dataclasses.dataclass(frozen=True)
class LoadPolicy:
    """Which components to materialize at cold start.

    eager_all        — the unoptimized baseline (everything up front).
    lazy set         — names/groups deferred to first use.
    prewarm set      — names compiled/materialized at startup even if
                       their group is lazy (profile-guided hot set).
    """
    lazy_groups: frozenset[str] = frozenset()
    lazy_names: frozenset[str] = frozenset()
    prewarm: frozenset[str] = frozenset()

    @staticmethod
    def eager_all() -> "LoadPolicy":
        return LoadPolicy()

    @staticmethod
    def from_report(report: dict, *, util_threshold=0.02) -> "LoadPolicy":
        """Build a policy from a SLIMSTART engine report: defer every
        component whose utilization is below threshold (paper's 2%)."""
        lazy = frozenset(
            row["component"] for row in report["components"]
            if row["utilization"] < util_threshold and row["init_s"] > 0)
        hot = frozenset(
            row["component"] for row in report["components"]
            if row["utilization"] >= util_threshold)
        return LoadPolicy(lazy_names=lazy, prewarm=hot)

    def is_lazy(self, comp: Component) -> bool:
        if comp.name in self.prewarm:
            return False
        return comp.group in self.lazy_groups or \
            comp.name in self.lazy_names


class ComponentRegistry:
    """Named components + init-time hierarchy (Eq. 1-3 at Level B)."""

    def __init__(self):
        self._comps: dict[str, Component] = {}

    def add(self, comp: Component):
        self._comps[comp.name] = comp
        return comp

    def __getitem__(self, name: str) -> Component:
        return self._comps[name]

    def __contains__(self, name):
        return name in self._comps

    def values(self):
        return self._comps.values()

    def materialize_eager(self, policy: LoadPolicy):
        for comp in self._comps.values():
            if not policy.is_lazy(comp):
                comp.get()
                comp.uses -= 1  # startup materialization isn't a use

    # ---------------------------------------------------- init hierarchy
    def total_init_time(self) -> float:
        return sum(c.init_time for c in self._comps.values())

    def group_init_times(self) -> dict[str, float]:
        out: dict[str, float] = {}
        for c in self._comps.values():
            out[c.group] = out.get(c.group, 0.0) + c.init_time
        return out

    def utilization(self) -> dict[str, float]:
        """Eq. 4 with component uses as the sample counts."""
        total = sum(c.uses for c in self._comps.values()) or 1
        return {c.name: c.uses / total for c in self._comps.values()}

    def report(self) -> dict:
        util = self.utilization()
        rows = [{
            "component": c.name,
            "group": c.group,
            "init_s": round(c.init_time, 4),
            "uses": c.uses,
            "utilization": round(util[c.name], 4),
            "ready": c.ready,
        } for c in self._comps.values()]
        rows.sort(key=lambda r: -r["init_s"])
        return {
            "total_init_s": round(self.total_init_time(), 4),
            "by_group": {k: round(v, 4)
                         for k, v in self.group_init_times().items()},
            "components": rows,
        }
