"""Continuous batching over a slot-based decode batch.

Requests arrive asynchronously; each is prefetched (prefill) into a free
slot of the shared decode batch, and one ``decode_fn`` step advances all
active slots together.  Finished slots free immediately (continuous
batching a la Orca/vLLM, slot-static variant for fixed XLA shapes).

Also hosts the serving-side straggler guard: a per-step deadline; steps
that exceed it are recorded and surface in the batcher stats (on real
multi-host serving the deadline triggers re-dispatch to a healthy
replica — here it is the observability hook).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray  # (prompt_len,)
    max_new_tokens: int
    arrival_s: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    finish_s: Optional[float] = None

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class ContinuousBatcher:
    """Drives (prefill_fn, decode_fn) over a fixed slot count.

    prefill_fn(tokens (1, L)) -> (first_token (1,), caches_b1)
    decode_fn(token (S, 1), pos (S,), caches) -> (next (S, 1), caches)
    where S = n_slots.  Caches are pytrees with a leading batch dim.
    """

    def __init__(self, prefill_fn, decode_fn, init_caches, *,
                 n_slots: int, eos_token: Optional[int] = None,
                 step_deadline_s: float = 5.0):
        self.prefill_fn = prefill_fn
        self.decode_fn = decode_fn
        self.caches = init_caches
        self.n_slots = n_slots
        self.eos = eos_token
        self.deadline = step_deadline_s
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros(n_slots, np.int32)
        self.cur = np.zeros(n_slots, np.int32)
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self.slow_steps = 0
        self.steps = 0

    # ------------------------------------------------------------ admin
    def submit(self, req: Request):
        req.arrival_s = req.arrival_s or time.perf_counter()
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    # ------------------------------------------------------------- step
    def _admit(self):
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.popleft()
            first, caches_1 = self.prefill_fn(
                jnp.asarray(req.tokens[None], jnp.int32))
            # splice the single-sequence cache into the batch at `slot`;
            # every cache leaf sits under a scan group, so the layout is
            # (layer_stack, batch, ...) — batch is axis 1
            self.caches = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_index_in_dim(
                    full, one[:, 0].astype(full.dtype), slot, 1),
                self.caches, caches_1)
            self.slots[slot] = req
            tok = int(np.asarray(first)[0])
            req.out_tokens.append(tok)
            self.cur[slot] = tok
            self.pos[slot] = len(req.tokens)

    def step(self) -> int:
        """Admit waiting requests, run one decode step; returns number of
        tokens produced."""
        self._admit()
        if self.active == 0:
            return 0
        t0 = time.perf_counter()
        tok = jnp.asarray(self.cur[:, None], jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        nxt, self.caches = self.decode_fn(tok, pos, self.caches)
        nxt = np.asarray(nxt).reshape(-1)
        dt = time.perf_counter() - t0
        self.steps += 1
        if dt > self.deadline:
            self.slow_steps += 1
        produced = 0
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            req.out_tokens.append(int(nxt[i]))
            self.cur[i] = int(nxt[i])
            self.pos[i] += 1
            produced += 1
            if req.done or (self.eos is not None
                            and int(nxt[i]) == self.eos):
                req.finish_s = time.perf_counter()
                self.finished.append(req)
                self.slots[i] = None
        return produced

    def run_until_drained(self, max_steps: int = 10_000):
        while (self.queue or self.active) and max_steps > 0:
            self.step()
            max_steps -= 1
        return self.stats()

    def stats(self) -> dict:
        lat = [r.finish_s - r.arrival_s for r in self.finished
               if r.finish_s]
        return {
            "finished": len(self.finished),
            "steps": self.steps,
            "slow_steps": self.slow_steps,
            "mean_latency_s": float(np.mean(lat)) if lat else None,
            "p99_latency_s": float(np.percentile(lat, 99)) if lat else
            None,
        }


def splice_batch_axis(tree_full, tree_one, slot: int):
    """Write batch-entry `slot` of tree_full from tree_one (batch 1);
    cache leaves are (layer_stack, batch, ...)."""
    return jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_index_in_dim(
            full, one[:, 0].astype(full.dtype), slot, 1),
        tree_full, tree_one)
