"""Serverless model-serving runtime with SLIMSTART cold-start control.

The engine decomposes a model server's cold start into *components*
(weight groups, modality frontends, per-entry-point compilations) — the
Level-B analogue of the paper's Python libraries — and applies the same
profile-guided loop: hierarchical init-cost breakdown, utilization from
live traffic, and lazy materialization of cold components.
"""

from repro.serving.components import (  # noqa: F401
    Component, ComponentRegistry, LoadPolicy,
)
from repro.serving.engine import (  # noqa: F401
    EnginePool, PoolSaturated, ServingEngine,
)
from repro.serving.batcher import ContinuousBatcher, Request  # noqa: F401
