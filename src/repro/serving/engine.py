"""Serverless serving engine with SLIMSTART-guided cold starts.

Cold-start anatomy (the Level-B "library loading"):
    import -> config -> weight materialization -> entry-point compilation
Each stage is a named ``Component``; the engine materializes the eager
set per ``LoadPolicy``, serves requests (materializing lazy components
on first use, exactly like a deferred import), and tracks per-entry
invocations + per-expert routing mass as the utilization signal for the
profile-guided optimizer (``engine.report()`` -> ``LoadPolicy.from_report``).

:class:`EnginePool` adds the fleet layer on top: pool-aware dispatch
across many models — requests route to a warm engine when one is
resident, fall back to a cold start (building and admitting a fresh
engine, evicting the worst-amortizing one past the budget), and the
pool's ``rewarm`` method plugs into
``SlimStartController(rewarm_fn=...)`` so a re-profile re-derives every
warm engine's load policy from its live utilization.
"""

from __future__ import annotations

import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.model import (
    decode_step, init_cache, init_params, model_template, prefill,
)
from repro.serving.components import Component, ComponentRegistry, LoadPolicy


class ServingEngine:
    """One model server instance ("function instance" in FaaS terms)."""

    def __init__(self, cfg: ArchConfig, *, policy: Optional[LoadPolicy]
                 = None, seed: int = 0, batch_size: int = 1,
                 prefill_len: int = 32, max_len: int = 96):
        self.cfg = cfg
        self.policy = policy or LoadPolicy.eager_all()
        self.seed = seed
        self.B = batch_size
        self.prefill_len = prefill_len
        self.max_len = max_len
        self.registry = ComponentRegistry()
        self.entry_counts: dict[str, int] = {}
        self.expert_mass: Optional[np.ndarray] = None
        self._params = None
        self.cold_start_s: Optional[float] = None
        self._build_components()

    # ------------------------------------------------------------ build
    def _build_components(self):
        cfg = self.cfg
        reg = self.registry
        key = jax.random.PRNGKey(self.seed)

        def weights_builder():
            params = init_params(cfg, key)
            if cfg.moe is not None:
                # expert FF weights are materialized per-expert instead
                params = self._blank_experts(params)
            return params

        reg.add(Component("weights.core", "weights", weights_builder))

        if cfg.moe is not None:
            for e in range(cfg.moe.n_experts):
                reg.add(Component(f"expert.{e}", "experts",
                                  partial(self._expert_builder, e)))
        if cfg.vision_tokens:
            reg.add(Component("frontend.vision", "frontend",
                              lambda: True))  # vision_proj kept in core;
            # the *stub tower* cost is modeled by the patch embedder
        if cfg.encoder_layers:
            reg.add(Component("frontend.audio_encoder", "frontend",
                              lambda: True))

        # per-entry-point compilations (AOT: lower+compile counted as the
        # component's init cost — the Level-B analogue of importing the
        # module that serves this handler)
        for entry in self.entries():
            reg.add(Component(f"compile.{entry}", "compile",
                              partial(self._compile_entry, entry)))

    def entries(self) -> list[str]:
        cfg = self.cfg
        out = ["generate"]
        if cfg.vision_tokens:
            out.append("vision_generate")
        if cfg.encoder_layers:
            out.append("transcribe")
        out.append("score")  # rarely-hit scoring/teacher-forcing handler
        return out

    # ---------------------------------------------------------- experts
    def _blank_experts(self, params):
        def blank(leaf_path_ok):
            return leaf_path_ok

        def visit(tree):
            for k, v in tree.items():
                if k == "moe":
                    v["wi"] = jnp.zeros_like(v["wi"])
                    v["wo"] = jnp.zeros_like(v["wo"])
                elif isinstance(v, dict):
                    visit(v)
        visit(params["layers"])
        return params

    def _expert_builder(self, e: int):
        """Materialize expert e's FF weights in every MoE layer and patch
        them into the live param tree."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), 1000 + e)
        params = self._params

        def visit(tree, path=""):
            for k, v in sorted(tree.items()):
                if k == "moe":
                    for w in ("wi", "wo"):
                        shape = v[w].shape  # (n_stack, E, ...)
                        sub = jax.random.normal(
                            jax.random.fold_in(key, hash((path, w)) %
                                               (2**31)),
                            shape[:1] + shape[2:], jnp.float32)
                        sub = (sub / np.sqrt(shape[2])).astype(v[w].dtype)
                        v[w] = v[w].at[:, e].set(sub)
                elif isinstance(v, dict):
                    visit(v, path + "/" + k)
        visit(params["layers"])
        return e

    # ------------------------------------------------------ compilation
    def _entry_shapes(self, entry: str):
        cfg = self.cfg
        B = self.B
        toks = jax.ShapeDtypeStruct((B, self.prefill_len), jnp.int32)
        extras = {}
        if entry == "vision_generate" and cfg.vision_tokens:
            extras["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), cfg.jdtype)
        if entry == "transcribe" and cfg.encoder_layers:
            extras["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), cfg.jdtype)
        return toks, extras

    def _compile_entry(self, entry: str):
        cfg = self.cfg
        toks, extras = self._entry_shapes(entry)
        cache_len = self.max_len + (cfg.vision_tokens or 0)

        if entry == "score":
            def score_fn(params, tokens):
                from repro.models.model import forward, _head
                h, _, _ = forward(cfg, params, tokens)
                return _head(cfg, params, h)
            compiled = jax.jit(score_fn).lower(
                self._param_shapes(), toks).compile()
            return {"score": compiled}

        def prefill_fn(params, tokens, extra):
            logits, caches, aux = prefill(cfg, params, tokens,
                                          cache_len=cache_len, **extra)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            load = aux.get("expert_load") if cfg.moe else None
            return nxt, caches, load

        def decode_fn(params, token, pos, caches):
            logits, caches = decode_step(cfg, params, token, pos, caches)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt[:, None], caches

        extra_shapes = {k: v for k, v in extras.items()}
        pre_c = jax.jit(prefill_fn).lower(
            self._param_shapes(), toks, extra_shapes).compile()
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, self.B, cache_len))
        dec_c = jax.jit(decode_fn).lower(
            self._param_shapes(),
            jax.ShapeDtypeStruct((self.B, 1), jnp.int32),
            jax.ShapeDtypeStruct((self.B,), jnp.int32),
            cache_shapes).compile()
        return {"prefill": pre_c, "decode": dec_c}

    def _param_shapes(self):
        return jax.eval_shape(
            lambda: init_params(self.cfg, jax.random.PRNGKey(0)))

    # ---------------------------------------------------------- serving
    def cold_start(self):
        """Materialize the eager set; returns wall seconds."""
        t0 = time.perf_counter()
        self._params = self.registry["weights.core"].get()
        self.registry["weights.core"].uses -= 1
        self.registry.materialize_eager(self.policy)
        self.cold_start_s = time.perf_counter() - t0
        return self.cold_start_s

    def _ensure(self, name: str):
        comp = self.registry[name]
        return comp.get()

    def serve(self, entry: str, tokens: np.ndarray, *,
              max_new_tokens: int = 8, extras: Optional[dict] = None):
        """Serve one batched request; returns (tokens_out, latency_s)."""
        t0 = time.perf_counter()
        cfg = self.cfg
        self.entry_counts[entry] = self.entry_counts.get(entry, 0) + 1
        if self._params is None:
            self._params = self.registry["weights.core"].get()
            self.registry["weights.core"].uses -= 1  # counted below
        exes = self._ensure(f"compile.{entry}")
        if entry == "vision_generate":
            self._ensure("frontend.vision")
        if entry == "transcribe":
            self._ensure("frontend.audio_encoder")

        self.registry["weights.core"].uses += 1  # every request hits them
        toks = jnp.asarray(tokens, jnp.int32)
        if entry == "score":
            out = exes["score"](self._params, toks)
            jax.block_until_ready(out)
            return np.asarray(out), time.perf_counter() - t0

        extra = dict(extras or {})
        _, extra_shapes = self._entry_shapes(entry)
        for k, sds in extra_shapes.items():
            if k not in extra:
                extra[k] = jnp.zeros(sds.shape, sds.dtype)

        nxt, caches, load = exes["prefill"](self._params, toks, extra)
        if load is not None:
            self._account_experts(np.asarray(load))
        vt = cfg.vision_tokens if entry == "vision_generate" else 0
        pos0 = toks.shape[1] + (vt or 0)
        out = [nxt]
        tok = nxt[:, None]
        for i in range(max_new_tokens - 1):
            pos = jnp.full((self.B,), pos0 + i, jnp.int32)
            tok, caches = exes["decode"](self._params, tok, pos, caches)
            out.append(tok[:, 0])
        result = np.stack([np.asarray(o) for o in out], axis=1)
        return result, time.perf_counter() - t0

    # ----------------------------------------- utilization / SLIMSTART
    def _account_experts(self, load: np.ndarray):
        """Routing mass -> expert Component.uses; materialize experts
        that received traffic but are still cold (lazy loading)."""
        if self.expert_mass is None:
            self.expert_mass = np.zeros_like(load)
        self.expert_mass += load
        for e, mass in enumerate(load):
            name = f"expert.{e}"
            if name in self.registry and mass > 0:
                comp = self.registry[name]
                if not comp.ready:
                    comp.get()  # deferred materialization on first route
                else:
                    comp.uses += 1

    def report(self) -> dict:
        rep = self.registry.report()
        rep["entry_counts"] = dict(self.entry_counts)
        rep["cold_start_s"] = self.cold_start_s
        if self.expert_mass is not None:
            tot = float(self.expert_mass.sum()) or 1.0
            rep["expert_utilization"] = {
                f"expert.{e}": round(float(m) / tot, 4)
                for e, m in enumerate(self.expert_mass)}
            # fold routing mass into component utilization rows
            for row in rep["components"]:
                if row["component"].startswith("expert."):
                    row["utilization"] = rep["expert_utilization"].get(
                        row["component"], 0.0)
        return rep


class EnginePool:
    """Pool-aware dispatch across warm :class:`ServingEngine` instances.

    The Level-B analogue of the zygote fleet
    (:class:`repro.pool.fleet.ZygoteFleet`): each *model* is an app,
    a warm engine is a resident instance, and ``max_warm`` is the shared
    budget.  ``dispatch`` routes a request to the model's warm engine;
    on a miss it cold-starts a fresh engine (``builders[model]``), and
    past the budget it evicts the warm engine that amortizes worst —
    fewest cold-start milliseconds saved per dispatch since admission —
    dropping its components so the memory is actually released.
    """

    def __init__(self, builders: dict[str, Callable[[], "ServingEngine"]],
                 *, max_warm: int = 2) -> None:
        if max_warm < 1:
            raise ValueError("max_warm must be >= 1")
        self.builders = dict(builders)
        self.max_warm = max_warm
        self.warm: dict[str, ServingEngine] = {}
        self.hits = 0
        self.misses = 0
        self.evictions: list[str] = []
        self._dispatches: dict[str, int] = {}

    # ----------------------------------------------------------- dispatch
    def dispatch(self, model: str, entry: str, tokens, **kw):
        """Serve one request; returns ``(output, latency_s, path)`` with
        ``path`` in {"warm", "cold"}.  Cold latency includes the
        engine's cold start, exactly like a FaaS cold invocation."""
        if model not in self.builders:
            raise KeyError(f"unknown model {model!r}")
        eng = self.warm.get(model)
        if eng is not None:
            self.hits += 1
            self._dispatches[model] = self._dispatches.get(model, 0) + 1
            out, lat = eng.serve(entry, tokens, **kw)
            return out, lat, "warm"
        self.misses += 1
        eng = self.builders[model]()
        cold_s = eng.cold_start()
        self._admit(model, eng)
        self._dispatches[model] = self._dispatches.get(model, 0) + 1
        out, lat = eng.serve(entry, tokens, **kw)
        return out, lat + cold_s, "cold"

    def _admit(self, model: str, eng: "ServingEngine") -> None:
        while len(self.warm) >= self.max_warm:
            victim = min(self.warm, key=self._amortization)
            dropped = self.warm.pop(victim)
            for comp in dropped.registry.values():
                comp.drop()
            self.evictions.append(victim)
            # a re-admitted model must not inherit its old residency's
            # dispatch count, or its amortization score starts inflated
            self._dispatches.pop(victim, None)
        self.warm[model] = eng

    def _amortization(self, model: str) -> float:
        """Cold-start seconds this engine saves per dispatch it served —
        low means the warm slot is wasted on it."""
        eng = self.warm[model]
        cold_s = eng.cold_start_s or 0.0
        return cold_s * self._dispatches.get(model, 0)

    # ------------------------------------------------------ adaptive hook
    def rewarm(self, report=None) -> dict:
        """``SlimStartController.rewarm_fn`` hook: after a re-profile,
        re-derive every warm engine's :class:`LoadPolicy` from its own
        live utilization report and materialize the new hot set.

        ``report`` takes anything :func:`repro.api.as_report` accepts
        (an :class:`~repro.core.profiler.report.OptimizationReport` or
        a saved versioned artifact path) for signature compatibility
        with the Level-A hooks; Level-B utilization lives in the warm
        engines themselves, so the artifact is validated but its
        contents are not consulted."""
        if report is not None:
            from repro.api.artifacts import as_report
            as_report(report)  # validate/normalize; Level-B ignores it
        from repro.serving.components import LoadPolicy
        out = {}
        for model, eng in self.warm.items():
            policy = LoadPolicy.from_report(eng.report())
            eng.policy = policy
            eng.registry.materialize_eager(policy)
            out[model] = sorted(policy.prewarm)
        return out

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "warm_models": sorted(self.warm),
            "hits": self.hits,
            "misses": self.misses,
            "hit_ratio": self.hits / max(total, 1),
            "evictions": list(self.evictions),
        }
