"""Serverless serving engine with SLIMSTART-guided cold starts.

Cold-start anatomy (the Level-B "library loading"):
    import -> config -> weight materialization -> entry-point compilation
Each stage is a named ``Component``; the engine materializes the eager
set per ``LoadPolicy``, serves requests (materializing lazy components
on first use, exactly like a deferred import), and tracks per-entry
invocations + per-expert routing mass as the utilization signal for the
profile-guided optimizer (``engine.report()`` -> ``LoadPolicy.from_report``).

:class:`EnginePool` adds the fleet layer on top: pool-aware dispatch
across many models — requests route to a warm engine when one is
resident, fall back to a cold start (building and admitting a fresh
engine, evicting the worst-amortizing one past the budget), and the
pool's ``rewarm`` method plugs into
``SlimStartController(rewarm_fn=...)`` so a re-profile re-derives every
warm engine's load policy from its live utilization.
"""

from __future__ import annotations

import threading
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.model import (
    decode_step, init_cache, init_params, model_template, prefill,
)
from repro.obs.tracing import get_tracer
from repro.serving.components import Component, ComponentRegistry, LoadPolicy


def _m_engine_dispatch(model: str, path: str) -> None:
    from repro.obs.metrics import default_registry
    default_registry().counter(
        "repro_engine_dispatch_total",
        "EnginePool dispatches by path (warm/cold/queued/shed)",
        labels=("model", "path")).labels(model=model, path=path).inc()


class ServingEngine:
    """One model server instance ("function instance" in FaaS terms)."""

    def __init__(self, cfg: ArchConfig, *, policy: Optional[LoadPolicy]
                 = None, seed: int = 0, batch_size: int = 1,
                 prefill_len: int = 32, max_len: int = 96):
        self.cfg = cfg
        self.policy = policy or LoadPolicy.eager_all()
        self.seed = seed
        self.B = batch_size
        self.prefill_len = prefill_len
        self.max_len = max_len
        self.registry = ComponentRegistry()
        self.entry_counts: dict[str, int] = {}
        self.expert_mass: Optional[np.ndarray] = None
        self._params = None
        self.cold_start_s: Optional[float] = None
        self._build_components()

    # ------------------------------------------------------------ build
    def _build_components(self):
        cfg = self.cfg
        reg = self.registry
        key = jax.random.PRNGKey(self.seed)

        def weights_builder():
            params = init_params(cfg, key)
            if cfg.moe is not None:
                # expert FF weights are materialized per-expert instead
                params = self._blank_experts(params)
            return params

        reg.add(Component("weights.core", "weights", weights_builder))

        if cfg.moe is not None:
            for e in range(cfg.moe.n_experts):
                reg.add(Component(f"expert.{e}", "experts",
                                  partial(self._expert_builder, e)))
        if cfg.vision_tokens:
            reg.add(Component("frontend.vision", "frontend",
                              lambda: True))  # vision_proj kept in core;
            # the *stub tower* cost is modeled by the patch embedder
        if cfg.encoder_layers:
            reg.add(Component("frontend.audio_encoder", "frontend",
                              lambda: True))

        # per-entry-point compilations (AOT: lower+compile counted as the
        # component's init cost — the Level-B analogue of importing the
        # module that serves this handler)
        for entry in self.entries():
            reg.add(Component(f"compile.{entry}", "compile",
                              partial(self._compile_entry, entry)))

    def entries(self) -> list[str]:
        cfg = self.cfg
        out = ["generate"]
        if cfg.vision_tokens:
            out.append("vision_generate")
        if cfg.encoder_layers:
            out.append("transcribe")
        out.append("score")  # rarely-hit scoring/teacher-forcing handler
        return out

    # ---------------------------------------------------------- experts
    def _blank_experts(self, params):
        def blank(leaf_path_ok):
            return leaf_path_ok

        def visit(tree):
            for k, v in tree.items():
                if k == "moe":
                    v["wi"] = jnp.zeros_like(v["wi"])
                    v["wo"] = jnp.zeros_like(v["wo"])
                elif isinstance(v, dict):
                    visit(v)
        visit(params["layers"])
        return params

    def _expert_builder(self, e: int):
        """Materialize expert e's FF weights in every MoE layer and patch
        them into the live param tree."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), 1000 + e)
        params = self._params

        def visit(tree, path=""):
            for k, v in sorted(tree.items()):
                if k == "moe":
                    for w in ("wi", "wo"):
                        shape = v[w].shape  # (n_stack, E, ...)
                        sub = jax.random.normal(
                            jax.random.fold_in(key, hash((path, w)) %
                                               (2**31)),
                            shape[:1] + shape[2:], jnp.float32)
                        sub = (sub / np.sqrt(shape[2])).astype(v[w].dtype)
                        v[w] = v[w].at[:, e].set(sub)
                elif isinstance(v, dict):
                    visit(v, path + "/" + k)
        visit(params["layers"])
        return e

    # ------------------------------------------------------ compilation
    def _entry_shapes(self, entry: str):
        cfg = self.cfg
        B = self.B
        toks = jax.ShapeDtypeStruct((B, self.prefill_len), jnp.int32)
        extras = {}
        if entry == "vision_generate" and cfg.vision_tokens:
            extras["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model), cfg.jdtype)
        if entry == "transcribe" and cfg.encoder_layers:
            extras["enc_frames"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), cfg.jdtype)
        return toks, extras

    def _compile_entry(self, entry: str):
        cfg = self.cfg
        toks, extras = self._entry_shapes(entry)
        cache_len = self.max_len + (cfg.vision_tokens or 0)

        if entry == "score":
            def score_fn(params, tokens):
                from repro.models.model import forward, _head
                h, _, _ = forward(cfg, params, tokens)
                return _head(cfg, params, h)
            compiled = jax.jit(score_fn).lower(
                self._param_shapes(), toks).compile()
            return {"score": compiled}

        def prefill_fn(params, tokens, extra):
            logits, caches, aux = prefill(cfg, params, tokens,
                                          cache_len=cache_len, **extra)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            load = aux.get("expert_load") if cfg.moe else None
            return nxt, caches, load

        def decode_fn(params, token, pos, caches):
            logits, caches = decode_step(cfg, params, token, pos, caches)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt[:, None], caches

        extra_shapes = {k: v for k, v in extras.items()}
        pre_c = jax.jit(prefill_fn).lower(
            self._param_shapes(), toks, extra_shapes).compile()
        cache_shapes = jax.eval_shape(
            lambda: init_cache(cfg, self.B, cache_len))
        dec_c = jax.jit(decode_fn).lower(
            self._param_shapes(),
            jax.ShapeDtypeStruct((self.B, 1), jnp.int32),
            jax.ShapeDtypeStruct((self.B,), jnp.int32),
            cache_shapes).compile()
        return {"prefill": pre_c, "decode": dec_c}

    def _param_shapes(self):
        return jax.eval_shape(
            lambda: init_params(self.cfg, jax.random.PRNGKey(0)))

    # ---------------------------------------------------------- serving
    def cold_start(self):
        """Materialize the eager set; returns wall seconds."""
        t0 = time.perf_counter()
        self._params = self.registry["weights.core"].get()
        self.registry["weights.core"].uses -= 1
        self.registry.materialize_eager(self.policy)
        self.cold_start_s = time.perf_counter() - t0
        return self.cold_start_s

    def _ensure(self, name: str):
        comp = self.registry[name]
        return comp.get()

    def serve(self, entry: str, tokens: np.ndarray, *,
              max_new_tokens: int = 8, extras: Optional[dict] = None):
        """Serve one batched request; returns (tokens_out, latency_s)."""
        t0 = time.perf_counter()
        cfg = self.cfg
        self.entry_counts[entry] = self.entry_counts.get(entry, 0) + 1
        if self._params is None:
            self._params = self.registry["weights.core"].get()
            self.registry["weights.core"].uses -= 1  # counted below
        exes = self._ensure(f"compile.{entry}")
        if entry == "vision_generate":
            self._ensure("frontend.vision")
        if entry == "transcribe":
            self._ensure("frontend.audio_encoder")

        self.registry["weights.core"].uses += 1  # every request hits them
        toks = jnp.asarray(tokens, jnp.int32)
        if entry == "score":
            out = exes["score"](self._params, toks)
            jax.block_until_ready(out)
            return np.asarray(out), time.perf_counter() - t0

        extra = dict(extras or {})
        _, extra_shapes = self._entry_shapes(entry)
        for k, sds in extra_shapes.items():
            if k not in extra:
                extra[k] = jnp.zeros(sds.shape, sds.dtype)

        nxt, caches, load = exes["prefill"](self._params, toks, extra)
        if load is not None:
            self._account_experts(np.asarray(load))
        vt = cfg.vision_tokens if entry == "vision_generate" else 0
        pos0 = toks.shape[1] + (vt or 0)
        out = [nxt]
        tok = nxt[:, None]
        for i in range(max_new_tokens - 1):
            pos = jnp.full((self.B,), pos0 + i, jnp.int32)
            tok, caches = exes["decode"](self._params, tok, pos, caches)
            out.append(tok[:, 0])
        result = np.stack([np.asarray(o) for o in out], axis=1)
        return result, time.perf_counter() - t0

    # ----------------------------------------- utilization / SLIMSTART
    def _account_experts(self, load: np.ndarray):
        """Routing mass -> expert Component.uses; materialize experts
        that received traffic but are still cold (lazy loading)."""
        if self.expert_mass is None:
            self.expert_mass = np.zeros_like(load)
        self.expert_mass += load
        for e, mass in enumerate(load):
            name = f"expert.{e}"
            if name in self.registry and mass > 0:
                comp = self.registry[name]
                if not comp.ready:
                    comp.get()  # deferred materialization on first route
                else:
                    comp.uses += 1

    def report(self) -> dict:
        rep = self.registry.report()
        rep["entry_counts"] = dict(self.entry_counts)
        rep["cold_start_s"] = self.cold_start_s
        if self.expert_mass is not None:
            tot = float(self.expert_mass.sum()) or 1.0
            rep["expert_utilization"] = {
                f"expert.{e}": round(float(m) / tot, 4)
                for e, m in enumerate(self.expert_mass)}
            # fold routing mass into component utilization rows
            for row in rep["components"]:
                if row["component"].startswith("expert."):
                    row["utilization"] = rep["expert_utilization"].get(
                        row["component"], 0.0)
        return rep


class PoolSaturated(RuntimeError):
    """Backpressure: a model's cold-start wait queue is full, the
    request was shed instead of piling more load on a cold pool."""


class EnginePool:
    """Pool-aware dispatch across warm :class:`ServingEngine` instances.

    The Level-B analogue of the zygote fleet
    (:class:`repro.pool.fleet.ZygoteFleet`): each *model* is an app,
    a warm engine is a resident instance, and ``max_warm`` is the shared
    budget.  ``dispatch`` routes a request to the model's warm engine;
    on a miss it cold-starts a fresh engine (``builders[model]``), and
    past the budget it evicts the warm engine that amortizes worst —
    fewest cold-start milliseconds saved per dispatch since admission —
    dropping its components so the memory is actually released.

    ``queue_depth`` turns on **queue-aware dispatch** for concurrent
    callers: while one thread cold-starts a model, other requests for
    the same model *wait* for that one engine instead of each building
    a duplicate (single-flight), at most ``queue_depth`` of them — the
    next raises :class:`PoolSaturated` and is counted as a shed.
    Waiters return with path ``"queued"`` and their wait recorded in
    ``queue_waits_s``.  ``queue_depth=None`` (default) keeps the
    legacy single-threaded behavior.
    """

    def __init__(self, builders: dict[str, Callable[[], "ServingEngine"]],
                 *, max_warm: int = 2,
                 queue_depth: Optional[int] = None,
                 fault_hook=None) -> None:
        if max_warm < 1:
            raise ValueError("max_warm must be >= 1")
        if queue_depth is not None and queue_depth < 0:
            raise ValueError("queue_depth must be >= 0")
        # chaos hook (repro.pool.chaos), called at the engine cold-start
        # site; None (default) leaves dispatch untouched
        self.fault_hook = fault_hook
        self.builders = dict(builders)
        self.max_warm = max_warm
        self.queue_depth = queue_depth
        self.warm: dict[str, ServingEngine] = {}
        self.hits = 0
        self.misses = 0
        self.sheds = 0
        self.evictions: list[str] = []
        self.queue_waits_s: list[float] = []
        self._dispatches: dict[str, int] = {}
        self._lock = threading.Lock()
        # model -> Event set once its in-flight cold start finishes
        self._cold_events: dict[str, threading.Event] = {}
        self._cold_waiters: dict[str, int] = {}
        # queue mode only: engines with serves in flight must not have
        # their components dropped under them by a concurrent eviction
        # — the drop is deferred until the last serve returns
        self._serving: dict[int, int] = {}          # id(engine) -> count
        self._drop_pending: dict[int, "ServingEngine"] = {}

    # ----------------------------------------------------------- dispatch
    def dispatch(self, model: str, entry: str, tokens, **kw):
        """Serve one request; returns ``(output, latency_s, path)`` with
        ``path`` in {"warm", "cold", "queued"}.  Cold latency includes
        the engine's cold start, exactly like a FaaS cold invocation;
        queued latency includes the wait for the in-flight one."""
        if model not in self.builders:
            raise KeyError(f"unknown model {model!r}")
        tracer = get_tracer()
        with tracer.span("engine_dispatch", model=model,
                         entry=entry) as sp:
            try:
                if self.queue_depth is None:
                    out, lat, path = self._dispatch_unlocked(
                        model, entry, tokens, _ctx=sp.ctx(), **kw)
                else:
                    out, lat, path = self._dispatch_queued(
                        model, entry, tokens, _ctx=sp.ctx(), **kw)
            except PoolSaturated:
                sp.set("path", "shed")
                _m_engine_dispatch(model, "shed")
                raise
            sp.set("path", path)
            _m_engine_dispatch(model, path)
            return out, lat, path

    def _dispatch_unlocked(self, model: str, entry: str, tokens,
                           _ctx: Optional[dict] = None, **kw):
        eng = self.warm.get(model)
        if eng is not None:
            self.hits += 1
            self._dispatches[model] = self._dispatches.get(model, 0) + 1
            out, lat = eng.serve(entry, tokens, **kw)
            return out, lat, "warm"
        self.misses += 1
        with get_tracer().span("cold_start", ctx=_ctx, model=model):
            if self.fault_hook is not None:
                self.fault_hook("cold_start", app=model)
            eng = self.builders[model]()
            cold_s = eng.cold_start()
        self._admit(model, eng)
        self._dispatches[model] = self._dispatches.get(model, 0) + 1
        out, lat = eng.serve(entry, tokens, **kw)
        return out, lat + cold_s, "cold"

    def _dispatch_queued(self, model: str, entry: str, tokens,
                         _ctx: Optional[dict] = None, **kw):
        t0 = time.perf_counter()
        waited = False
        wait_s = 0.0
        while True:
            evt: Optional[threading.Event] = None
            with self._lock:
                eng = self.warm.get(model)
                if eng is not None:
                    self.hits += 1
                    self._dispatches[model] = \
                        self._dispatches.get(model, 0) + 1
                    if waited:
                        wait_s = time.perf_counter() - t0
                        self.queue_waits_s.append(wait_s)
                    path = "queued" if waited else "warm"
                elif model not in self._cold_events:
                    # we are the builder: single-flight the cold start
                    self._cold_events[model] = threading.Event()
                    path = "build"
                else:
                    if self._cold_waiters.get(model, 0) \
                            >= self.queue_depth:
                        self.sheds += 1
                        raise PoolSaturated(
                            f"model {model!r}: {self.queue_depth} "
                            f"requests already wait on its cold start")
                    self._cold_waiters[model] = \
                        self._cold_waiters.get(model, 0) + 1
                    evt = self._cold_events[model]
                    path = "wait"
            if path in ("warm", "queued"):
                out, lat = self._serve_counted(eng, entry, tokens, **kw)
                return out, lat + wait_s, path
            if path == "build":
                try:
                    with get_tracer().span("cold_start", ctx=_ctx,
                                           model=model):
                        if self.fault_hook is not None:
                            self.fault_hook("cold_start", app=model)
                        eng = self.builders[model]()
                        cold_s = eng.cold_start()
                    with self._lock:
                        self.misses += 1
                        self._admit(model, eng)
                        self._dispatches[model] = \
                            self._dispatches.get(model, 0) + 1
                finally:
                    # wake waiters even on a failed build — one of them
                    # retries as the next builder
                    with self._lock:
                        self._cold_events.pop(model).set()
                out, lat = self._serve_counted(eng, entry, tokens, **kw)
                return out, lat + cold_s, "cold"
            # path == "wait": block until the in-flight build finishes
            evt.wait()
            with self._lock:
                self._cold_waiters[model] = max(
                    self._cold_waiters.get(model, 1) - 1, 0)
            waited = True

    def _serve_counted(self, eng: "ServingEngine", entry: str, tokens,
                       **kw):
        """Serve while holding an in-flight ticket on the engine so a
        concurrent eviction defers its component drop (queue mode)."""
        key = id(eng)
        with self._lock:
            self._serving[key] = self._serving.get(key, 0) + 1
        try:
            return eng.serve(entry, tokens, **kw)
        finally:
            with self._lock:
                n = self._serving.get(key, 1) - 1
                if n > 0:
                    self._serving[key] = n
                else:
                    self._serving.pop(key, None)
                    pending = self._drop_pending.pop(key, None)
                    if pending is not None:
                        for comp in pending.registry.values():
                            comp.drop()

    def _admit(self, model: str, eng: "ServingEngine") -> None:
        while len(self.warm) >= self.max_warm:
            victim = min(self.warm, key=self._amortization)
            dropped = self.warm.pop(victim)
            if self._serving.get(id(dropped), 0) > 0:
                # a thread is mid-serve on the victim: dropping its
                # components now would yield None mid-request — defer
                # to the last in-flight serve's exit
                self._drop_pending[id(dropped)] = dropped
            else:
                for comp in dropped.registry.values():
                    comp.drop()
            self.evictions.append(victim)
            # a re-admitted model must not inherit its old residency's
            # dispatch count, or its amortization score starts inflated
            self._dispatches.pop(victim, None)
        # a builder may hand back the same engine object that was
        # evicted earlier (cached/singleton builders): cancel any
        # still-pending deferred drop or it would fire after this
        # re-admission and gut a warm engine
        self._drop_pending.pop(id(eng), None)
        self.warm[model] = eng

    def _amortization(self, model: str) -> float:
        """Cold-start seconds this engine saves per dispatch it served —
        low means the warm slot is wasted on it."""
        eng = self.warm[model]
        cold_s = eng.cold_start_s or 0.0
        return cold_s * self._dispatches.get(model, 0)

    # ------------------------------------------------------ adaptive hook
    def shared_hot_components(self, *, min_models: int = 2,
                              util_threshold: float = 0.02) -> list[str]:
        """The Level-B analogue of the fleet's cross-app shared hot set
        (:mod:`repro.pool.sharing`): component names hot (utilization
        >= threshold) for at least ``min_models`` of the warm engines.
        A fresh cold start's policy prewarms these even when its own
        model has no utilization history yet — the pool-wide base
        layer every member keeps paying for anyway."""
        from repro.pool.sharing import intersect_hot_sets
        hot_sets = {}
        for model, eng in self.warm.items():
            report = getattr(eng, "report", None)
            if report is None:  # duck-typed engine without utilization
                continue
            rep = report()
            hot_sets[model] = [row["component"]
                               for row in rep["components"]
                               if row["utilization"] >= util_threshold]
        # component names are a flat namespace ("expert.1"/"expert.2"
        # share no loadable parent): exact-name intersection only
        return sorted(intersect_hot_sets(hot_sets,
                                         min_members=min_models,
                                         prefixes=False))

    def rewarm(self, report=None) -> dict:
        """``SlimStartController.rewarm_fn`` hook: after a re-profile,
        re-derive every warm engine's :class:`LoadPolicy` from its own
        live utilization report — *plus* the pool's shared hot
        components (see :meth:`shared_hot_components`), so a component
        the rest of the pool keeps hot is never deferred by one
        engine's thin local history — and materialize the new set.

        ``report`` takes anything :func:`repro.api.as_report` accepts
        (an :class:`~repro.core.profiler.report.OptimizationReport` or
        a saved versioned artifact path) for signature compatibility
        with the Level-A hooks; Level-B utilization lives in the warm
        engines themselves, so the artifact is validated but its
        contents are not consulted."""
        if report is not None:
            from repro.api.artifacts import as_report
            as_report(report)  # validate/normalize; Level-B ignores it
        from repro.serving.components import LoadPolicy
        shared = frozenset(self.shared_hot_components())
        out = {}
        for model, eng in self.warm.items():
            policy = LoadPolicy.from_report(eng.report())
            policy = LoadPolicy(
                lazy_groups=policy.lazy_groups,
                lazy_names=policy.lazy_names - shared,
                prewarm=policy.prewarm
                | {c for c in shared if c in eng.registry})
            eng.policy = policy
            eng.registry.materialize_eager(policy)
            out[model] = sorted(policy.prewarm)
        return out

    def stats(self) -> dict:
        total = self.hits + self.misses
        waits = sorted(self.queue_waits_s)
        return {
            "warm_models": sorted(self.warm),
            "shared_hot_components": self.shared_hot_components(),
            "hits": self.hits,
            "misses": self.misses,
            "hit_ratio": self.hits / max(total, 1),
            "evictions": list(self.evictions),
            "sheds": self.sheds,
            # every EnginePool shed has one cause; keyed like the fleet
            # summary's breakdown so dashboards can merge the two
            "shed_reasons": ({"pool-saturated": self.sheds}
                             if self.sheds else {}),
            "coalesced": len(self.queue_waits_s),
            "queue_wait_p99_s": (
                waits[min(len(waits) - 1,
                          max(0, round(0.99 * (len(waits) - 1))))]
                if waits else 0.0),
        }
