"""Structural FLOP/byte model for every (arch x shape) cell.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies once, so a
21-period layer scan under-reports FLOPs ~21x.  This model reconstructs
per-step totals from the model definition itself — every matmul the
layers actually issue (attention, MLP, MoE dispatch einsums, recurrent
gates, embedding/logits) — and is validated against cost_analysis on
small *unrolled* configs (tests/test_costmodel.py, <10% error).

Conventions:
  * one MAC = 2 FLOPs;
  * train  = fwd + bwd (2x) + block-remat recompute (+1x fwd) = 4x fwd;
  * decode counts one new token against a seq_len cache;
  * bytes  = HBM traffic per device per step (params/opt/grad + KV + a
    2-pass activation estimate), the roofline memory term.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models.config import ArchConfig, ShapeSpec
from repro.models.model import block_pattern_of, param_count


@dataclasses.dataclass
class CellCost:
    fwd_flops: float  # per step, global (all devices)
    step_flops: float  # incl. bwd/remat for train
    model_flops: float  # 6*N*D (train) / 2*N*D (serve) reference
    hbm_bytes: float  # per device per step
    params: int
    active_params: int


def _attn_flops(cfg: ArchConfig, tokens: int, kv_len: float) -> float:
    """QK^T + PV for one layer: 2 einsums x 2 FLOPs x H x hd."""
    return 4.0 * tokens * kv_len * cfg.n_heads * cfg.head_dim


def _proj_flops(cfg: ArchConfig, tokens: int) -> float:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return 2.0 * tokens * D * (H * hd + 2 * K * hd + H * hd)


def _mlp_flops(cfg: ArchConfig, tokens: int, d_ff: Optional[int] = None
               ) -> float:
    F = d_ff if d_ff is not None else cfg.d_ff
    return 2.0 * tokens * cfg.d_model * 3 * F  # gate+up+down


def _moe_flops(cfg: ArchConfig, tokens: int) -> float:
    e = cfg.moe
    group = cfg.moe_group
    D, E, k, F = cfg.d_model, e.n_experts, e.top_k, e.d_expert_ff
    cap = max(int(e.capacity_factor * min(group, tokens) * k / E), 1)
    n_groups = max(tokens // group, 1)
    slots = n_groups * E * cap  # expert-slot tokens actually computed
    flops = 2.0 * tokens * D * E  # router
    flops += 2.0 * slots * D * 3 * F  # expert gate+up+down
    # dispatch/combine one-hot einsums (the dense-dispatch overhead the
    # ragged path removes):   xin (E,C,D) = disp (S,E,C) . x (S,D) etc.
    flops += 2.0 * 2 * tokens * E * cap * D
    return flops


def _block_flops(cfg: ArchConfig, kind: str, tokens: int, *,
                 kv_len: float, cross_len: float = 0.0) -> float:
    D = cfg.d_model
    f = 0.0
    if kind.startswith("attn"):
        f += _proj_flops(cfg, tokens)
        f += _attn_flops(cfg, tokens, kv_len)
    elif kind == "rglru":
        R = cfg.rglru_dim or D
        f += 2.0 * tokens * D * (2 * R)  # wx, wg
        f += 2.0 * tokens * R * D  # wo
        f += 2.0 * tokens * R * (2 * R)  # w_a, w_i gates
        f += tokens * R * (cfg.conv_width * 2 + 10)  # conv + scan ops
    elif kind == "mlstm":
        nh = cfg.lru_heads or cfg.n_heads
        dh = D // nh
        f += 2.0 * tokens * D * (4 * D + 2 * nh)  # q,k,v,og + gates
        f += 2.0 * tokens * D * D  # wo
        f += tokens * nh * (4 * dh * dh + 6 * dh)  # C update + readout
    elif kind == "slstm":
        nh = cfg.lru_heads or cfg.n_heads
        dh = D // nh
        f += 2.0 * tokens * D * (4 * D) + 2.0 * tokens * D * D
        f += 2.0 * tokens * nh * 4 * dh * dh  # block-diag recurrence
    if cross_len:
        f += _proj_flops(cfg, tokens)
        f += _attn_flops(cfg, tokens, cross_len)
    if cfg.moe is not None and kind.startswith("attn"):
        f += _moe_flops(cfg, tokens)
    elif cfg.d_ff > 0:
        f += _mlp_flops(cfg, tokens)
    return f


def _kv_len_for(cfg: ArchConfig, kind: str, shape: ShapeSpec) -> float:
    S = shape.seq_len
    if shape.kind == "train" or shape.kind == "prefill":
        if kind == "attn_local":
            w = cfg.window_size
            return min(w, S) / 1.0 if S > w else S / 2.0
        return S / 2.0  # causal average
    # decode: one token against the cache
    if kind == "attn_local":
        return min(cfg.window_size, S)
    return S


def forward_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    pat = block_pattern_of(cfg)
    S = shape.seq_len
    B = shape.global_batch
    tokens = B * (1 if shape.kind == "decode" else S)
    total = 0.0
    cross = cfg.encoder_seq if cfg.encoder_layers else 0.0
    for li in range(cfg.n_layers):
        kind = pat[li % len(pat)]
        total += _block_flops(cfg, kind, tokens,
                              kv_len=_kv_len_for(cfg, kind, shape),
                              cross_len=cross)
    # encoder (whisper): bidirectional full attention over 1500 frames
    if cfg.encoder_layers and shape.kind != "decode":
        enc_tokens = B * cfg.encoder_seq
        for _ in range(cfg.encoder_layers):
            total += _proj_flops(cfg, enc_tokens)
            total += _attn_flops(cfg, enc_tokens, cfg.encoder_seq)
            total += _mlp_flops(cfg, enc_tokens)
    # embedding lookup is a gather; logits are a matmul
    if shape.kind == "train":
        total += 2.0 * tokens * cfg.d_model * cfg.vocab
    elif shape.kind == "prefill":
        total += 2.0 * B * cfg.d_model * cfg.vocab  # last position only
    else:
        total += 2.0 * B * cfg.d_model * cfg.vocab
    return total


def cell_cost(cfg: ArchConfig, shape: ShapeSpec, *, n_devices: int = 256,
              train_multiplier: float = 4.0) -> CellCost:
    fwd = forward_flops(cfg, shape)
    if shape.kind == "train":
        step = fwd * train_multiplier
    else:
        step = fwd
    N = param_count(cfg)
    Na = cfg.active_param_count() if cfg.moe else N
    tokens = shape.global_batch * (1 if shape.kind == "decode"
                                   else shape.seq_len)
    model = (6.0 if shape.kind == "train" else 2.0) * Na * tokens

    # ---- per-device HBM bytes ----
    dt = 2  # bf16
    p_local = N * dt / min(n_devices, 16)  # TP over the model axis
    if shape.kind == "train":
        # params r + grads w + master/mu/nu r/w (f32, ZeRO over mesh)
        opt_local = 3 * N * 4 / n_devices
        bytes_dev = p_local * 2 + opt_local * 2
        # activations: ~12 r/w of (tokens, D) bf16 per layer (fwd+bwd)
        act = 12.0 * tokens * cfg.d_model * dt * cfg.n_layers / n_devices
        bytes_dev += act
    elif shape.kind == "prefill":
        act = 8.0 * tokens * cfg.d_model * dt * cfg.n_layers / n_devices
        kv_write = _kv_cache_bytes(cfg, shape) / n_devices
        bytes_dev = p_local + act + kv_write
    else:  # decode: params + full KV read dominate
        kv = _kv_cache_bytes(cfg, shape) / n_devices
        bytes_dev = p_local + kv
    return CellCost(fwd_flops=fwd, step_flops=step, model_flops=model,
                    hbm_bytes=bytes_dev, params=N, active_params=Na)


def _kv_cache_bytes(cfg: ArchConfig, shape: ShapeSpec) -> float:
    pat = block_pattern_of(cfg)
    S, B = shape.seq_len, shape.global_batch
    dt = 2
    # int8 KV: 1 byte codes + one f32 scale per (token, kv-head)
    dt_g = 1 + 4.0 / cfg.head_dim if cfg.kv_quant == "int8" else dt
    total = 0.0
    for li in range(cfg.n_layers):
        kind = pat[li % len(pat)]
        if kind == "attn_global":
            total += 2 * B * S * cfg.n_kv_heads * cfg.head_dim * dt_g
        elif kind == "attn_local":
            L = min(cfg.window_size, S)
            total += 2 * B * L * cfg.n_kv_heads * cfg.head_dim * dt
        elif kind == "rglru":
            total += B * (cfg.rglru_dim or cfg.d_model) * 4
        elif kind in ("mlstm", "slstm"):
            nh = cfg.lru_heads or cfg.n_heads
            dh = cfg.d_model // nh
            total += B * nh * dh * dh * 4
    if cfg.encoder_layers and shape.kind == "decode":
        total += (2 * B * cfg.encoder_seq * cfg.n_kv_heads * cfg.head_dim
                  * dt * cfg.n_layers)
    return total
