"""Benchmark orchestrator — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]

Level A (the paper, measured on this container's subprocess cells):
    Fig.1 init ratio, Fig.2 STAT/DYN, Fig.3 skew, Table II speedups,
    Table III FaaSLight, Fig.8 memory, Fig.9 overhead, Fig.10 adaptive.
Level B (TPU-native adaptation): serving cold starts.
Roofline: merged from the dry-run artifacts if present.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"

    # import after BENCH_QUICK is set (common.py reads it at import)
    from benchmarks import (
        bench_adaptive, bench_faaslight_compare, bench_fleet,
        bench_init_ratio, bench_memory, bench_profiler_overhead,
        bench_serving_coldstart, bench_speedup_table,
        bench_static_vs_dynamic, bench_workload_skew,
    )

    benches = [
        ("workload_skew", bench_workload_skew.run),          # Fig. 3
        ("adaptive", bench_adaptive.run),                    # Fig. 10
        ("init_ratio", bench_init_ratio.run),                # Fig. 1
        ("static_vs_dynamic", bench_static_vs_dynamic.run),  # Fig. 2
        ("speedup_table", bench_speedup_table.run),          # Table II
        ("faaslight_compare", bench_faaslight_compare.run),  # Table III
        ("memory", bench_memory.run),                        # Fig. 8
        ("profiler_overhead", bench_profiler_overhead.run),  # Fig. 9
        ("serving_coldstart", bench_serving_coldstart.run),  # Level B
        ("fleet", bench_fleet.run),                          # fleet scale
    ]

    results = {}
    failures = []
    for name, fn in benches:
        if args.only and args.only != name:
            continue
        print(f"\n{'=' * 72}\n[bench] {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            results[name] = fn()
            print(f"[bench] {name} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception as e:  # pragma: no cover
            failures.append(name)
            traceback.print_exc()
            print(f"[bench] {name} FAILED: {e}")

    # roofline summary (reads dry-run artifacts if the sweep has run)
    if not args.only or args.only == "roofline":
        try:
            from benchmarks.roofline import load_cells, to_markdown
            rows = load_cells("baseline", "sp1")
            if rows:
                print(f"\n{'=' * 72}\n[bench] roofline "
                      f"({len(rows)} cells)\n{'=' * 72}")
                print(to_markdown(rows))
                results["roofline_cells"] = len(rows)
        except Exception:
            traceback.print_exc()

    print("\n" + "=" * 72)
    print(f"[bench] complete: {len(results)} ok, {len(failures)} failed"
          + (f" ({failures})" if failures else ""))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
