"""Benchmark orchestrator — one registry entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]
                                            [--list]

The suite is discovered, not hand-maintained: every ``bench_*.py``
module registers its ``run`` via the ``@bench(...)`` decorator in
``benchmarks.common``; this orchestrator imports the modules and walks
the registry in suite order.  ``--list`` prints the registry (including
non-default entries, runnable via ``--only``).

Level A (the paper, measured on this container's subprocess cells):
    Fig.1 init ratio, Fig.2 STAT/DYN, Fig.3 skew, Table II speedups,
    Table III FaaSLight, Fig.8 memory, Fig.9 overhead, Fig.10 adaptive.
Level B (TPU-native adaptation): serving cold starts.
Fleet: multi-app zygote fleet replay.
Roofline: merged from the dry-run artifacts if present.
"""

from __future__ import annotations

import argparse
import importlib
import os
import pkgutil
import sys
import time
import traceback


def _import_bench_modules() -> None:
    """Import every benchmarks.bench_* module so @bench registers it."""
    pkg_dir = os.path.dirname(os.path.abspath(__file__))
    for info in pkgutil.iter_modules([pkg_dir]):
        if info.name.startswith("bench_"):
            importlib.import_module(f"benchmarks.{info.name}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="print the bench registry and exit")
    args = ap.parse_args()
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"

    # import after BENCH_QUICK is set (common.py reads it at import)
    from benchmarks.common import BENCHES, registered_benches
    _import_bench_modules()

    if args.list:
        for e in registered_benches(include_non_default=True):
            flag = "" if e.default else "  [--only]"
            print(f"{e.order:>4}  {e.name:<22} {e.ref}{flag}")
        return

    entries = registered_benches(only=args.only)
    if args.only and not entries and args.only != "roofline":
        print(f"unknown bench {args.only!r}; registered: "
              f"{sorted(BENCHES)}", file=sys.stderr)
        sys.exit(2)

    results = {}
    failures = []
    for entry in entries:
        print(f"\n{'=' * 72}\n[bench] {entry.name}"
              + (f" ({entry.ref})" if entry.ref else "")
              + f"\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            results[entry.name] = entry.fn()
            print(f"[bench] {entry.name} done in {time.time() - t0:.1f}s",
                  flush=True)
        except Exception as e:  # pragma: no cover
            failures.append(entry.name)
            traceback.print_exc()
            print(f"[bench] {entry.name} FAILED: {e}")

    # roofline summary (reads dry-run artifacts if the sweep has run)
    if not args.only or args.only == "roofline":
        try:
            from benchmarks.roofline import load_cells, to_markdown
            rows = load_cells("baseline", "sp1")
            if rows:
                print(f"\n{'=' * 72}\n[bench] roofline "
                      f"({len(rows)} cells)\n{'=' * 72}")
                print(to_markdown(rows))
                results["roofline_cells"] = len(rows)
        except Exception:
            traceback.print_exc()

    print("\n" + "=" * 72)
    print(f"[bench] complete: {len(results)} ok, {len(failures)} failed"
          + (f" ({failures})" if failures else ""))
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
