"""Fig. 3 / Observation 3 — entry-point count PDF and invocation CDF.

Replays the suite's handler weights (calibrated to the production-trace
statistics the paper reports: 54% of functions have >1 entry point; the
top handlers take >80% of cumulative invocations).
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.benchsuite.genlibs import build_suite

from benchmarks.common import bench, save_result, table


@bench("workload_skew", ref="Fig. 3", order=10)
def run() -> dict:
    root = build_suite()
    apps_dir = os.path.join(root, "apps")
    counts = []
    all_weights = []
    for app in sorted(os.listdir(apps_dir)):
        meta = json.load(open(os.path.join(apps_dir, app, "meta.json")))
        weights = meta.get("handlers", {})
        counts.append(len(weights))
        if weights:
            all_weights.append(sorted(weights.values(), reverse=True))

    counts = np.array(counts)
    multi = float((counts > 1).mean())
    # CDF of invocation mass by handler rank (averaged over apps)
    max_h = max(len(w) for w in all_weights)
    cdf = np.zeros(max_h)
    for w in all_weights:
        c = np.cumsum(np.pad(w, (0, max_h - len(w))))
        cdf += c
    cdf /= len(all_weights)
    top1 = float(cdf[0])
    top2 = float(cdf[min(1, max_h - 1)])

    pdf_rows = [{"n_handlers": int(k),
                 "fraction": round(float((counts == k).mean()), 3)}
                for k in sorted(set(counts))]
    payload = {
        "figure": "Fig. 3 / Obs. 3",
        "claims": {
            "paper_multi_entry_fraction": 0.54,
            "ours_multi_entry_fraction": round(multi, 3),
            "paper_top_handlers_over_80pct": True,
            "ours_top1_mass": round(top1, 3),
            "ours_top2_mass": round(top2, 3),
        },
        "pdf": pdf_rows,
        "cdf_by_rank": [round(float(x), 3) for x in cdf],
    }
    save_result("bench_workload_skew", payload)
    print(table(pdf_rows, ["n_handlers", "fraction"],
                "Fig. 3(1) PDF of #entry points"))
    print(f"multi-entry fraction: {multi:.2f} (paper 0.54); "
          f"top-2 handler mass: {top2:.2f} (paper >0.8)")
    return payload


if __name__ == "__main__":
    run()
