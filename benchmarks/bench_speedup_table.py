"""Table II — init / e2e / p99 speedups from SLIMSTART optimization.

For every optimization-candidate app: baseline cold starts -> full
SLIMSTART pipeline (profile N instances -> CCT/U(L) analysis -> AST
deferred-import rewrite) -> optimized cold starts.  Reports mean and
p99 speedups plus memory, mirroring the paper's Table II columns.
"""

from __future__ import annotations

import os

from repro.api import SlimStart
from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts

from benchmarks.common import (
    ALL_OPT_APPS, APP_SHORT, N_COLD, N_INSTANCES, N_INVOKE, bench,
    save_result, table,
)


def optimize_and_measure(app: str, root: str) -> dict:
    base_dir = os.path.join(root, "apps", app)
    base = measure_cold_starts(base_dir, n=N_COLD)
    res = SlimStart.profile_guided(
        app, root, instances=N_INSTANCES, invocations=N_INVOKE).run()
    opt = measure_cold_starts(res.variant_dir, n=N_COLD)
    return {
        "app": APP_SHORT.get(app, app),
        "deferred_imports": res.apply_summary["deferred"],
        "init_speedup": round(base.init_mean / opt.init_mean, 2),
        "e2e_speedup": round(base.e2e_mean / opt.e2e_mean, 2),
        "p99_init_speedup": round(base.init_p99 / opt.init_p99, 2),
        "p99_e2e_speedup": round(base.e2e_p99 / opt.e2e_p99, 2),
        "mem_reduction": round(base.rss_mean_mb / opt.rss_mean_mb, 2),
        "base_init_ms": round(base.init_mean, 1),
        "opt_init_ms": round(opt.init_mean, 1),
        "base_rss_mb": round(base.rss_mean_mb, 1),
        "opt_rss_mb": round(opt.rss_mean_mb, 1),
    }


@bench("speedup_table", ref="Table II", order=50)
def run(apps=None) -> dict:
    root = build_suite()
    rows = [optimize_and_measure(app, root)
            for app in (apps or ALL_OPT_APPS)]
    best_init = max(r["init_speedup"] for r in rows)
    best_e2e = max(r["e2e_speedup"] for r in rows)
    best_mem = max(r["mem_reduction"] for r in rows)
    payload = {
        "table": "Table II",
        "claims": {
            "paper_best_init_speedup": 2.30,
            "paper_best_e2e_speedup": 2.26,
            "paper_best_mem_reduction": 1.51,
            "ours_best_init_speedup": best_init,
            "ours_best_e2e_speedup": best_e2e,
            "ours_best_mem_reduction": best_mem,
        },
        "rows": rows,
    }
    save_result("bench_speedup_table", payload)
    print(table(rows, ["app", "deferred_imports", "init_speedup",
                       "e2e_speedup", "p99_init_speedup",
                       "p99_e2e_speedup", "mem_reduction"],
                "Table II speedups"))
    print(f"best: init {best_init}x (paper 2.30x), e2e {best_e2e}x "
          f"(paper 2.26x), mem {best_mem}x (paper 1.51x)")
    return payload


if __name__ == "__main__":
    run()
