"""Fig. 8 — peak-memory reduction from SLIMSTART optimization.

Reads Table II measurements (bench_speedup_table) if present — memory is
measured in the same cold-start runs — otherwise measures a subset.
"""

from __future__ import annotations

from benchmarks.common import bench, load_result, save_result, table


@bench("memory", ref="Fig. 8", order=70)
def run() -> dict:
    tab = load_result("bench_speedup_table")
    if tab is None:
        import benchmarks.bench_speedup_table as bst
        tab = bst.run()
    rows = [{
        "app": r["app"],
        "base_rss_mb": r["base_rss_mb"],
        "opt_rss_mb": r["opt_rss_mb"],
        "mem_reduction": r["mem_reduction"],
    } for r in tab["rows"]]
    best = max(r["mem_reduction"] for r in rows)
    payload = {
        "figure": "Fig. 8",
        "claims": {"paper_best_mem_reduction": 1.51,
                   "ours_best_mem_reduction": best},
        "rows": rows,
    }
    save_result("bench_memory", payload)
    print(table(rows, ["app", "base_rss_mb", "opt_rss_mb",
                       "mem_reduction"], "Fig. 8 memory"))
    return payload


if __name__ == "__main__":
    run()
