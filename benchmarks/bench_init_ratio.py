"""Fig. 1 — ratio of library initialization time to end-to-end time.

Measures real subprocess cold starts for every app and reports
init / e2e; the paper finds >70% for most apps (our suite is calibrated
to the same regime) and <10% for the trivial apps (which are then
excluded from optimization, §IV-A1).
"""

from __future__ import annotations

import os

from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts

from benchmarks.common import (
    ALL_OPT_APPS, APP_SHORT, LOW_INIT, N_COLD, bench, save_result, table,
)


@bench("init_ratio", ref="Fig. 1", order=30)
def run() -> dict:
    root = build_suite()
    rows = []
    for app in ALL_OPT_APPS + LOW_INIT:
        stats = measure_cold_starts(
            os.path.join(root, "apps", app), n=N_COLD)
        ratio = stats.init_mean / stats.e2e_mean
        rows.append({
            "app": APP_SHORT.get(app, app),
            "init_ms": round(stats.init_mean, 1),
            "e2e_ms": round(stats.e2e_mean, 1),
            "ratio": round(ratio, 3),
            "optimization_candidate": ratio >= 0.10,
        })
    majority = sum(r["ratio"] > 0.5 for r in rows[:len(ALL_OPT_APPS)])
    payload = {
        "figure": "Fig. 1",
        "claim": "library init dominates cold-start e2e for most apps",
        "apps_over_50pct": majority,
        "n_opt_apps": len(ALL_OPT_APPS),
        "rows": rows,
    }
    save_result("bench_init_ratio", payload)
    print(table(rows, ["app", "init_ms", "e2e_ms", "ratio",
                       "optimization_candidate"], "Fig. 1 init/e2e"))
    return payload


if __name__ == "__main__":
    run()
