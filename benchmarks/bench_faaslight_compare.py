"""Table III — SLIMSTART (measured) vs FaaSLight (reported + our static
re-implementation) on the five FaaSLight apps: runtime memory and
end-to-end latency, before/after.
"""

from __future__ import annotations

import os

from repro.api import SlimStart
from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts

from benchmarks.common import (
    APP_SHORT, FAASLIGHT, N_COLD, N_INSTANCES, N_INVOKE, bench,
    save_result, table,
)

# FaaSLight's reported before/after (paper Table III), for side-by-side
PAPER_REPORTED = {
    "price_ml_predict": {"mem": (142, 140), "e2e": (4534.38, 4004.10)},
    "skimage_numpy": {"mem": (228, 130), "e2e": (7165.54, 4152.73)},
    "train_wine_ml": {"mem": (230, 216), "e2e": (9035.39, 7470.49)},
    "predict_wine_ml": {"mem": (230, 215), "e2e": (8291.80, 7071.03)},
    "sentiment_analysis_fl": {"mem": (182, 141), "e2e": (5551.03, 3934.31)},
}


@bench("faaslight_compare", ref="Table III", order=60)
def run() -> dict:
    root = build_suite()
    rows = []
    for app in FAASLIGHT:
        base_dir = os.path.join(root, "apps", app)
        base = measure_cold_starts(base_dir, n=N_COLD)
        static_res = SlimStart.static_baseline(app, root).run()
        static = measure_cold_starts(static_res.variant_dir, n=N_COLD)
        slim_res = SlimStart.profile_guided(
            app, root, instances=N_INSTANCES, invocations=N_INVOKE).run()
        slim = measure_cold_starts(slim_res.variant_dir, n=N_COLD)
        rep = PAPER_REPORTED.get(app, {})
        rows.append({
            "app": APP_SHORT.get(app, app),
            "faaslight_reported_e2e_speedup": round(
                rep["e2e"][0] / rep["e2e"][1], 2) if rep else None,
            "static_e2e_speedup": round(
                base.e2e_mean / static.e2e_mean, 2),
            "slimstart_e2e_speedup": round(
                base.e2e_mean / slim.e2e_mean, 2),
            "faaslight_reported_mem_reduction": round(
                rep["mem"][0] / rep["mem"][1], 2) if rep else None,
            "static_mem_reduction": round(
                base.rss_mean_mb / static.rss_mean_mb, 2),
            "slimstart_mem_reduction": round(
                base.rss_mean_mb / slim.rss_mean_mb, 2),
        })
    wins = sum(r["slimstart_e2e_speedup"] > r["static_e2e_speedup"]
               for r in rows)
    payload = {
        "table": "Table III",
        "claims": {
            "paper_app11_slimstart_e2e": 2.01,
            "paper_app11_faaslight_e2e": 1.41,
            "slimstart_beats_static_count": wins,
            "n_apps": len(rows),
        },
        "rows": rows,
    }
    save_result("bench_faaslight_compare", payload)
    print(table(rows, ["app", "faaslight_reported_e2e_speedup",
                       "static_e2e_speedup", "slimstart_e2e_speedup",
                       "slimstart_mem_reduction"],
                "Table III vs FaaSLight"))
    return payload


if __name__ == "__main__":
    run()
