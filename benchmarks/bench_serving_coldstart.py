"""Level-B (DESIGN.md §2): SLIMSTART on model-serving cold starts.

The TPU-native adaptation: "libraries" = server components (weight
groups, modality frontends, per-expert slices, per-entry compilations).
For representative reduced archs we measure real cold starts (weight
init + XLA compile on this CPU) under three policies:

  eager      — materialize + compile everything (unoptimized baseline)
  lazy-all   — defer everything (first requests pay)
  slimstart  — profile-guided: run the eager service under the skewed
               workload, build LoadPolicy.from_report (2% utilization
               threshold), re-deploy

and replay the same skewed workload, reporting cold-start time, hot-path
first-request latency, and the e2e of the whole trace.
"""

from __future__ import annotations

import numpy as np

from repro.configs import get_reduced
from repro.launch.serve import run_service, skewed_workload
from repro.serving import LoadPolicy, ServingEngine

from benchmarks.common import QUICK, bench, save_result, table

ARCHS = ["granite-moe-1b-a400m", "whisper-large-v3", "pixtral-12b"]
if not QUICK:
    ARCHS += ["qwen2.5-32b"]


@bench("serving_coldstart", ref="Level B", order=90)
def run() -> dict:
    rows = []
    n_req = 12 if QUICK else 24
    for arch in ARCHS:
        cfg = get_reduced(arch)
        entries = ServingEngine(cfg, batch_size=1).entries()
        workload = skewed_workload(entries, n_req, seed=1)
        hot = workload[0]

        # eager baseline (+ profile source for the slimstart policy)
        eng_e, cold_e, lat_e = run_service(cfg, LoadPolicy.eager_all(),
                                           workload, seed=1)
        policy = LoadPolicy.from_report(eng_e.report())

        eng_l, cold_l, lat_l = run_service(
            cfg, LoadPolicy(lazy_groups=frozenset(
                {"compile", "frontend", "experts"})), workload, seed=1)
        eng_s, cold_s, lat_s = run_service(cfg, policy, workload, seed=1)

        def first(latmap):
            return latmap[hot][0]

        def total(latmap):
            return sum(sum(v) for v in latmap.values())

        rows.append({
            "arch": arch,
            "cold_eager_s": round(cold_e, 3),
            "cold_lazy_s": round(cold_l, 3),
            "cold_slimstart_s": round(cold_s, 3),
            "coldstart_speedup": round(cold_e / max(cold_s, 1e-9), 2),
            "first_hot_req_eager_s": round(first(lat_e), 3),
            "first_hot_req_lazy_s": round(first(lat_l), 3),
            "first_hot_req_slim_s": round(first(lat_s), 3),
            "trace_e2e_eager_s": round(cold_e + total(lat_e), 3),
            "trace_e2e_lazy_s": round(cold_l + total(lat_l), 3),
            "trace_e2e_slim_s": round(cold_s + total(lat_s), 3),
            "deferred_components": len(policy.lazy_names),
        })
    payload = {
        "experiment": "Level-B serving cold start (DESIGN.md §2)",
        "rows": rows,
        "claims": {
            "slimstart_beats_eager_coldstart": all(
                r["cold_slimstart_s"] < r["cold_eager_s"] for r in rows),
            "slimstart_hot_path_not_penalized": all(
                r["first_hot_req_slim_s"] <=
                r["first_hot_req_lazy_s"] * 1.5 + 0.05 for r in rows),
            "mean_coldstart_speedup": round(float(np.mean(
                [r["coldstart_speedup"] for r in rows])), 2),
        },
    }
    save_result("bench_serving_coldstart", payload)
    print(table(rows, ["arch", "cold_eager_s", "cold_lazy_s",
                       "cold_slimstart_s", "coldstart_speedup",
                       "first_hot_req_slim_s", "trace_e2e_slim_s"],
                "Level-B serving cold start"))
    return payload


if __name__ == "__main__":
    run()
