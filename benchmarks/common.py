"""Shared helpers for the benchmark suite."""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "results"

# Suite-wide sizing: QUICK=1 trims cold-start repetitions so the whole
# suite runs in minutes on one CPU core; the full setting mirrors the
# paper's 500-cold-start protocol at a scale this container can run.
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
N_COLD = 3 if QUICK else 8
N_INVOKE = 40 if QUICK else 150
N_INSTANCES = 2 if QUICK else 4

# the paper's per-suite app sets
RAINBOWCAKE = ["dna_visualisation", "graph_bfs", "graph_mst",
               "graph_pagerank", "sentiment_analysis_r"]
FAASLIGHT = ["price_ml_predict", "skimage_numpy", "predict_wine_ml",
             "train_wine_ml", "sentiment_analysis_fl"]
FAASWORKBENCH = ["chameleon", "model_training", "model_serving"]
REALWORLD = ["ocrmypdf", "cve_bin_tool", "sensor_telemetry",
             "heart_failure"]
LOW_INIT = ["echo", "json_transform", "wordcount", "matrix_small",
            "thumbnail"]  # <10% init share: excluded from optimization
ALL_OPT_APPS = RAINBOWCAKE + FAASLIGHT + FAASWORKBENCH + REALWORLD

APP_SHORT = {
    "dna_visualisation": "R-DV", "graph_bfs": "R-GB", "graph_mst": "R-GM",
    "graph_pagerank": "R-GPR", "sentiment_analysis_r": "R-SA",
    "price_ml_predict": "FL-PMP", "skimage_numpy": "FL-SN",
    "predict_wine_ml": "FL-PWM", "train_wine_ml": "FL-TWM",
    "sentiment_analysis_fl": "FL-SA", "chameleon": "FWB-CML",
    "model_training": "FWB-MT", "model_serving": "FWB-MS",
    "ocrmypdf": "OCRmyPDF", "cve_bin_tool": "CVE-bin-tool",
    "sensor_telemetry": "SensorTD", "heart_failure": "HFP",
}


def save_result(name: str, payload) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    path = RESULTS / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2))
    return path


def load_result(name: str):
    path = RESULTS / f"{name}.json"
    if path.exists():
        return json.loads(path.read_text())
    return None


def table(rows: list[dict], cols: list[str], title: str = "") -> str:
    if title:
        out = [f"== {title} =="]
    else:
        out = []
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols} if rows else {c: len(c) for c in cols}
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  ".join(_fmt(r.get(c)).ljust(widths[c])
                             for c in cols))
    return "\n".join(out)


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


class timed:
    def __init__(self, label):
        self.label = label

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        print(f"[{self.label}] {time.time() - self.t0:.1f}s")
