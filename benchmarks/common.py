"""Shared helpers for the benchmark suite.

Also home of the **bench registry**: each ``bench_*.py`` module
decorates its ``run`` with :func:`bench`, and ``benchmarks/run.py``
discovers the suite from :func:`registered_benches` instead of a
hand-maintained list.  Results are written/read as versioned
``bench_result`` artifacts (:mod:`repro.api.artifacts`); legacy raw
payload JSONs under ``results/`` still load via the v1 migration path.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Optional

# one table renderer for benches and the CLI (see repro.api.render)
from repro.api.render import fmt_cell as _fmt, table  # noqa: F401

RESULTS = Path(__file__).resolve().parent / "results"


# ---------------------------------------------------------------------------
# Bench registry
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BenchEntry:
    name: str
    fn: Callable[[], dict]
    ref: str = ""  # which paper figure/table this reproduces
    order: int = 100  # suite position (deps like memory<-speedup_table)
    default: bool = True  # part of the default `-m benchmarks.run` sweep


BENCHES: dict[str, BenchEntry] = {}


def bench(name: str, *, ref: str = "", order: int = 100,
          default: bool = True):
    """Register a benchmark's ``run`` function with the suite."""
    def deco(fn: Callable[[], dict]) -> Callable[[], dict]:
        if name in BENCHES:
            raise ValueError(f"duplicate bench registration {name!r}")
        BENCHES[name] = BenchEntry(name=name, fn=fn, ref=ref,
                                   order=order, default=default)
        return fn
    return deco


def registered_benches(only: Optional[str] = None, *,
                       include_non_default: bool = False
                       ) -> list[BenchEntry]:
    """Registry entries in suite order.  ``only`` selects one by name
    (non-default entries included); ``include_non_default`` returns
    the whole registry (for listings)."""
    entries = sorted(BENCHES.values(), key=lambda e: (e.order, e.name))
    if only is not None:
        return [e for e in entries if e.name == only]
    if include_non_default:
        return entries
    return [e for e in entries if e.default]

# Suite-wide sizing: QUICK=1 trims cold-start repetitions so the whole
# suite runs in minutes on one CPU core; the full setting mirrors the
# paper's 500-cold-start protocol at a scale this container can run.
QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
N_COLD = 3 if QUICK else 8
N_INVOKE = 40 if QUICK else 150
N_INSTANCES = 2 if QUICK else 4

# the paper's per-suite app sets
RAINBOWCAKE = ["dna_visualisation", "graph_bfs", "graph_mst",
               "graph_pagerank", "sentiment_analysis_r"]
FAASLIGHT = ["price_ml_predict", "skimage_numpy", "predict_wine_ml",
             "train_wine_ml", "sentiment_analysis_fl"]
FAASWORKBENCH = ["chameleon", "model_training", "model_serving"]
REALWORLD = ["ocrmypdf", "cve_bin_tool", "sensor_telemetry",
             "heart_failure"]
LOW_INIT = ["echo", "json_transform", "wordcount", "matrix_small",
            "thumbnail"]  # <10% init share: excluded from optimization
ALL_OPT_APPS = RAINBOWCAKE + FAASLIGHT + FAASWORKBENCH + REALWORLD

APP_SHORT = {
    "dna_visualisation": "R-DV", "graph_bfs": "R-GB", "graph_mst": "R-GM",
    "graph_pagerank": "R-GPR", "sentiment_analysis_r": "R-SA",
    "price_ml_predict": "FL-PMP", "skimage_numpy": "FL-SN",
    "predict_wine_ml": "FL-PWM", "train_wine_ml": "FL-TWM",
    "sentiment_analysis_fl": "FL-SA", "chameleon": "FWB-CML",
    "model_training": "FWB-MT", "model_serving": "FWB-MS",
    "ocrmypdf": "OCRmyPDF", "cve_bin_tool": "CVE-bin-tool",
    "sensor_telemetry": "SensorTD", "heart_failure": "HFP",
}


def save_result(name: str, payload) -> Path:
    """Write a ``bench_result`` artifact (atomic, schema-versioned)."""
    from repro.api import save_bench_result
    path = RESULTS / f"{name}.json"
    save_bench_result(name, payload, str(path))
    return path


def load_result(name: str):
    """Load a ``bench_result`` artifact (legacy raw payloads migrate)."""
    from repro.api import load_bench_result
    path = RESULTS / f"{name}.json"
    if path.exists():
        return load_bench_result(str(path))
    return None




def measure_boot_pair(app_dir: str, hot: list, delta: list, base) -> dict:
    """Time one app's zygote boot both ways: fresh interpreter +
    full hot set vs forked from the shared ``base`` + private delta.

    Shared by ``bench_fleet`` and ``bench_pool_policies`` so the
    timing boundaries (ForkServer.start() to ready, zygote torn down
    between measurements) cannot drift between the two benchmarks.
    Returns ``{"boot_fresh_ms", "boot_shared_ms", "boot_speedup",
    "fresh_rss_mb", "incremental_mb"}`` — ``incremental_mb`` is the
    spawned zygote's private pages when the kernel reports a real
    split, else its RSS increment over the base.
    """
    from repro.pool.forkserver import ForkServer
    t0 = time.perf_counter()
    fs = ForkServer(app_dir, preload=hot)
    fs.start()
    fresh_ms = (time.perf_counter() - t0) * 1e3
    fresh_rss_mb = fs.rss_kb() / 1024.0
    fs.stop()
    base_rss_mb = base.rss_kb() / 1024.0
    t0 = time.perf_counter()
    fs2 = ForkServer(app_dir, preload=delta, base=base)
    fs2.start()
    spawn_ms = (time.perf_counter() - t0) * 1e3
    mem = fs2.memory_kb()
    incremental_mb = (mem["private_kb"] / 1024.0 if mem["pss_kb"] > 0
                      else max(mem["rss_kb"] / 1024.0 - base_rss_mb,
                               0.0))
    fs2.stop()
    return {
        "boot_fresh_ms": round(fresh_ms, 1),
        "boot_shared_ms": round(spawn_ms, 1),
        "boot_speedup": round(fresh_ms / max(spawn_ms, 1e-9), 2),
        "fresh_rss_mb": round(fresh_rss_mb, 1),
        "incremental_mb": round(incremental_mb, 1),
    }


class timed:
    def __init__(self, label):
        self.label = label

    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        print(f"[{self.label}] {time.time() - self.t0:.1f}s")
