"""Fig. 9 — runtime overhead of SLIMSTART-Profiler.

Warm per-invocation time with vs without the sampling profiler attached;
the paper reports <=10% for most apps at the default sampling rate.

Also benchmarks the span tracer (``repro.obs.tracing``) in its default
*disabled* state: instrumentation stays inline on the serving hot path,
so a disabled ``tracer.span(...)`` must cost roughly nothing compared
to the work it wraps.
"""

from __future__ import annotations

import os
import time

from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_warm_overhead

from benchmarks.common import (
    ALL_OPT_APPS, APP_SHORT, N_INVOKE, QUICK, bench, save_result, table,
)


def measure_tracer_overhead(iterations: int = 50_000) -> dict:
    """Per-operation cost (ns) of the tracer, disabled vs enabled.

    The "work" inside each span is a single perf_counter() call so the
    numbers reflect tracer overhead, not the payload.
    """
    from repro.obs.tracing import configure_tracing, get_tracer

    def loop(tracer) -> float:
        t0 = time.perf_counter()
        for _ in range(iterations):
            with tracer.span("bench"):
                time.perf_counter()
        return (time.perf_counter() - t0) / iterations * 1e9

    def baseline() -> float:
        t0 = time.perf_counter()
        for _ in range(iterations):
            time.perf_counter()
        return (time.perf_counter() - t0) / iterations * 1e9

    configure_tracing(enabled=False)
    tracer = get_tracer()
    # min-of-3 to shave scheduler noise
    base_ns = min(baseline() for _ in range(3))
    disabled_ns = min(loop(tracer) for _ in range(3))
    configure_tracing(enabled=True)
    tracer = get_tracer()
    enabled_ns = min(loop(tracer) for _ in range(3))
    tracer.clear()
    configure_tracing(enabled=False)
    return {
        "iterations": iterations,
        "baseline_ns": round(base_ns, 1),
        "disabled_span_ns": round(disabled_ns - base_ns, 1),
        "enabled_span_ns": round(enabled_ns - base_ns, 1),
    }


@bench("profiler_overhead", ref="Fig. 9", order=80)
def run() -> dict:
    root = build_suite()
    apps = ALL_OPT_APPS if not QUICK else ALL_OPT_APPS[:6]
    rows = []
    for app in apps:
        base_ms, prof_ms = measure_warm_overhead(
            os.path.join(root, "apps", app), invocations=N_INVOKE)
        rows.append({
            "app": APP_SHORT.get(app, app),
            "base_ms": round(base_ms, 3),
            "profiled_ms": round(prof_ms, 3),
            "overhead_pct": round(100 * (prof_ms / base_ms - 1), 1),
        })
    under10 = sum(r["overhead_pct"] <= 10 for r in rows)
    tracer = measure_tracer_overhead(iterations=5_000 if QUICK else 50_000)
    payload = {
        "figure": "Fig. 9",
        "claims": {
            "paper": "most apps <=10% overhead",
            "ours_under_10pct": under10,
            "n_apps": len(rows),
            "ours_mean_overhead_pct": round(
                sum(r["overhead_pct"] for r in rows) / len(rows), 2),
        },
        "rows": rows,
        "tracer": tracer,
    }
    save_result("bench_profiler_overhead", payload)
    print(table(rows, ["app", "base_ms", "profiled_ms", "overhead_pct"],
                "Fig. 9 profiler overhead"))
    print(f"span tracer: disabled {tracer['disabled_span_ns']:.0f} ns/span, "
          f"enabled {tracer['enabled_span_ns']:.0f} ns/span "
          f"(baseline {tracer['baseline_ns']:.0f} ns)")
    return payload


if __name__ == "__main__":
    run()
