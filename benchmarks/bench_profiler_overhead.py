"""Fig. 9 — runtime overhead of SLIMSTART-Profiler.

Warm per-invocation time with vs without the sampling profiler attached;
the paper reports <=10% for most apps at the default sampling rate.
"""

from __future__ import annotations

import os

from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_warm_overhead

from benchmarks.common import (
    ALL_OPT_APPS, APP_SHORT, N_INVOKE, QUICK, bench, save_result, table,
)


@bench("profiler_overhead", ref="Fig. 9", order=80)
def run() -> dict:
    root = build_suite()
    apps = ALL_OPT_APPS if not QUICK else ALL_OPT_APPS[:6]
    rows = []
    for app in apps:
        base_ms, prof_ms = measure_warm_overhead(
            os.path.join(root, "apps", app), invocations=N_INVOKE)
        rows.append({
            "app": APP_SHORT.get(app, app),
            "base_ms": round(base_ms, 3),
            "profiled_ms": round(prof_ms, 3),
            "overhead_pct": round(100 * (prof_ms / base_ms - 1), 1),
        })
    under10 = sum(r["overhead_pct"] <= 10 for r in rows)
    payload = {
        "figure": "Fig. 9",
        "claims": {
            "paper": "most apps <=10% overhead",
            "ours_under_10pct": under10,
            "n_apps": len(rows),
            "ours_mean_overhead_pct": round(
                sum(r["overhead_pct"] for r in rows) / len(rows), 2),
        },
        "rows": rows,
    }
    save_result("bench_profiler_overhead", payload)
    print(table(rows, ["app", "base_ms", "profiled_ms", "overhead_pct"],
                "Fig. 9 profiler overhead"))
    return payload


if __name__ == "__main__":
    run()
