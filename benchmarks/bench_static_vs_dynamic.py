"""Fig. 2 / Observation 2 — static reachability (STAT) vs dynamic
profiling (DYN) on the FaaSLight apps.

STAT defers only provably-unreachable imports; DYN additionally defers
reachable-but-unused (workload-dependent) libraries found by sampling.
We report each method's deferred init share and the measured e2e —
the paper's point is DYN's upper bound is far larger (avg 50.68% vs
static's 19.21% reduction).
"""

from __future__ import annotations

import os

from repro.api import SlimStart
from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts

from benchmarks.common import (
    APP_SHORT, FAASLIGHT, N_COLD, N_INSTANCES, N_INVOKE, bench,
    save_result, table,
)


@bench("static_vs_dynamic", ref="Fig. 2", order=40)
def run() -> dict:
    root = build_suite()
    rows = []
    for app in FAASLIGHT:
        base_dir = os.path.join(root, "apps", app)
        base = measure_cold_starts(base_dir, n=N_COLD)

        static = SlimStart.static_baseline(app, root).run()
        stat = measure_cold_starts(static.variant_dir, n=N_COLD)

        dyn_res = SlimStart.profile_guided(
            app, root, instances=N_INSTANCES, invocations=N_INVOKE).run()
        dyn = measure_cold_starts(dyn_res.variant_dir, n=N_COLD)

        rows.append({
            "app": APP_SHORT.get(app, app),
            "stat_deferred": static.apply_summary["deferred"],
            "dyn_deferred": dyn_res.apply_summary["deferred"],
            "stat_init_cut_pct": round(
                100 * (1 - stat.init_mean / base.init_mean), 1),
            "dyn_init_cut_pct": round(
                100 * (1 - dyn.init_mean / base.init_mean), 1),
            "stat_e2e_speedup": round(base.e2e_mean / stat.e2e_mean, 2),
            "dyn_e2e_speedup": round(base.e2e_mean / dyn.e2e_mean, 2),
        })
    avg_stat = sum(r["stat_init_cut_pct"] for r in rows) / len(rows)
    avg_dyn = sum(r["dyn_init_cut_pct"] for r in rows) / len(rows)
    payload = {
        "figure": "Fig. 2 / Obs. 2",
        "claims": {
            "paper_static_avg_cut_pct": 19.21,
            "paper_dynamic_avg_cut_pct": 50.68,
            "ours_static_avg_cut_pct": round(avg_stat, 2),
            "ours_dynamic_avg_cut_pct": round(avg_dyn, 2),
            "dynamic_beats_static": avg_dyn > avg_stat,
        },
        "rows": rows,
    }
    save_result("bench_static_vs_dynamic", payload)
    print(table(rows, ["app", "stat_deferred", "dyn_deferred",
                       "stat_init_cut_pct", "dyn_init_cut_pct",
                       "stat_e2e_speedup", "dyn_e2e_speedup"],
                "Fig. 2 STAT vs DYN"))
    print(f"avg init cut: static {avg_stat:.1f}% vs dynamic "
          f"{avg_dyn:.1f}% (paper: 19.2% vs 50.7%)")
    return payload


if __name__ == "__main__":
    run()
