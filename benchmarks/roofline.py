"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:
    compute term    = step_FLOPs / (chips x 197 TF/s bf16)
    memory term     = HBM_bytes_per_chip / 819 GB/s
    collective term = collective_bytes_per_chip / 50 GB/s  (loop-aware
                      HLO parse from the dry-run; per-partition shapes)
Compute/memory come from the structural cost model (costmodel.py) because
cost_analysis counts loop bodies once — the raw cost_analysis numbers are
kept alongside for reference.  Dominant term = max of the three; the
roofline fraction = compute / dominant (1.0 = compute-bound at peak,
assuming perfect overlap).

    PYTHONPATH=src python -m benchmarks.roofline [--tag baseline] [--md]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.mesh import HW
from repro.models import SHAPES

from benchmarks.costmodel import cell_cost

RESULTS = Path(__file__).resolve().parent / "results"
DRYRUN = RESULTS / "dryrun"


def analyze_cell(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    if rec.get("overrides"):
        cfg = cfg.with_(**{k: v for k, v in rec["overrides"].items()
                           if not isinstance(v, (list, dict))})
    shape = SHAPES[rec["shape"]]
    chips = rec["n_devices"]
    cost = cell_cost(cfg, shape, n_devices=chips)

    t_compute = cost.step_flops / (chips * HW["peak_flops_bf16"])
    t_memory = cost.hbm_bytes / HW["hbm_bw"]
    coll_bytes = rec["collectives"]["total"]  # per device (per-partition)
    t_coll = coll_bytes / HW["ici_bw"]

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    t_max = max(terms.values()) or 1e-12
    frac = t_compute / t_max
    hlo_flops_raw = rec["cost"].get("flops") or 0.0
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "tag": rec.get("tag", "baseline"),
        "chips": chips,
        "multi_pod": rec["multi_pod"],
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": frac,
        "model_flops": cost.model_flops,
        "step_flops": cost.step_flops,
        "useful_ratio": cost.model_flops / max(cost.step_flops, 1.0),
        "hbm_gb_per_dev": cost.hbm_bytes / 1e9,
        "coll_gb_per_dev": coll_bytes / 1e9,
        "temp_gb": (rec["memory"].get("temp_bytes") or 0) / 1e9,
        "args_gb": (rec["memory"].get("argument_bytes") or 0) / 1e9,
        "fits_hbm": ((rec["memory"].get("temp_bytes") or 0)
                     + (rec["memory"].get("argument_bytes") or 0))
        <= HW["hbm_bytes"] * 1.0,
        "cost_analysis_flops_raw": hlo_flops_raw,
        "compile_s": rec.get("compile_s"),
    }


def load_cells(tag: str = "baseline", pod: str = "sp1") -> list[dict]:
    out = []
    for f in sorted(DRYRUN.glob(f"*__{pod}__{tag}.json")):
        out.append(analyze_cell(json.loads(f.read_text())))
    return out


def bottleneck_note(row: dict) -> str:
    d = row["dominant"]
    if d == "compute":
        return ("compute-bound: larger per-chip tiles / fewer chips or "
                "higher MFU kernels move it")
    if d == "memory":
        return ("HBM-bound: KV/weight quantization or higher arithmetic "
                "intensity (bigger batch) moves it")
    return ("collective-bound: overlap/reschedule collectives, shard to "
            "cut resharding, or compress traffic")


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | roofline | useful | fits |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_ratio']:.2f} | "
            f"{'y' if r['fits_hbm'] else 'N'} |")
    return hdr + "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--pod", default="sp1")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    rows = load_cells(args.tag, args.pod)
    if not rows:
        raise SystemExit(f"no dry-run results for tag={args.tag}")
    out = RESULTS / f"roofline_{args.tag}_{args.pod}.json"
    out.write_text(json.dumps(rows, indent=2))
    if args.md:
        print(to_markdown(rows))
    else:
        for r in rows:
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"C={r['compute_s']:.2e} M={r['memory_s']:.2e} "
                  f"X={r['collective_s']:.2e} dom={r['dominant']:10s} "
                  f"roof={r['roofline_fraction']:.2f} "
                  f"useful={r['useful_ratio']:.2f}")
    print(f"\n[roofline] wrote {out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
