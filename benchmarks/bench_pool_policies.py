"""Warm-pool policy comparison: fork-server vs fresh cold starts, and
trace-driven fleet simulation across keep-alive policies.

Part 1 (real measurements): for each app, run the SLIMSTART pipeline to
get the profile-guided hot set, then measure the same app three ways —
fresh-process cold starts, bare fork-pool starts (zygote shares only
the interpreter), and profile-guided fork-pool starts (zygote
pre-imports the hot set).  The fork-pool warm path must come in >=2x
faster than fresh cold starts (HotSwap-style amortization on top of the
paper's deferral).

Part 2 (simulation): feed the measured per-app profile into the fleet
simulator and sweep every keep-alive policy over all four trace shapes
(poisson / diurnal / bursty / handler-skewed), reporting cold-start
ratio, p50/p99 latency, and memory GB-seconds per (policy, trace).
"""

from __future__ import annotations

import copy
import os

from repro.api import SlimStart
from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts, measure_pool_starts
from repro.pool.forkserver import BaseZygote
from repro.pool.policies import default_policies, hot_set_from_report
from repro.pool.sharing import compute_shared_hot_set, shared_search_paths
from repro.pool.simulator import AppProfile, FleetSimulator
from repro.pool.trace import standard_traces

from benchmarks.common import (
    APP_SHORT, N_COLD, N_INSTANCES, N_INVOKE, QUICK, bench,
    measure_boot_pair, save_result, table,
)

POOL_APPS = ["graph_bfs", "sentiment_analysis_r"]
TRACE_DURATION_S = 600.0 if QUICK else 1200.0


def measure_app(root: str, app: str) -> dict:
    """Pipeline -> hot set -> fresh vs bare-pool vs hot-pool starts."""
    res = SlimStart.profile_guided(
        app, root, instances=N_INSTANCES, invocations=N_INVOKE).run()
    hot = hot_set_from_report(res.report)
    app_dir = os.path.join(root, "apps", app)
    fresh = measure_cold_starts(app_dir, n=N_COLD)
    bare = measure_pool_starts(app_dir, n=N_COLD)
    warm = measure_pool_starts(app_dir, n=N_COLD, preload=hot)
    return {
        "app": app,
        "report": res.report,
        "hot_set": hot,
        "fresh": fresh,
        "bare_pool": bare,
        "hot_pool": warm,
    }


@bench("pool_policies", ref="warm-pool policies", order=110,
       default=False)
def run() -> dict:
    root = build_suite()

    # -------------------------------------------- part 1: real fork-pool
    rows = []
    measured = {}
    for app in POOL_APPS:
        m = measure_app(root, app)
        measured[app] = m
        rows.append({
            "app": APP_SHORT.get(app, app),
            "fresh_init_ms": round(m["fresh"].init_mean, 1),
            "pool_init_ms": round(m["bare_pool"].init_mean, 1),
            "hot_pool_init_ms": round(m["hot_pool"].init_mean, 1),
            "speedup_bare": round(m["fresh"].init_mean
                                  / m["bare_pool"].init_mean, 2),
            "speedup_hot": round(m["fresh"].init_mean
                                 / m["hot_pool"].init_mean, 2),
            "hot_set": ",".join(m["hot_set"]),
        })
    print(table(rows, ["app", "fresh_init_ms", "pool_init_ms",
                       "hot_pool_init_ms", "speedup_bare", "speedup_hot",
                       "hot_set"],
                "Fork-pool vs fresh-process cold starts"))

    # ------------------------------- part 1b: shared-base zygote boot
    # the two-tier column: boot each app's zygote fresh (interpreter +
    # hot set) vs fork it from one shared base — the per-app *zygote
    # boot* cost the fleet pays on deploy, rewarm and crash recovery
    app_dirs = {a: os.path.join(root, "apps", a) for a in POOL_APPS}
    shared = compute_shared_hot_set(
        {a: m["report"] for a, m in measured.items()}, min_apps=2)
    base = BaseZygote(preload=shared.modules,
                      search_paths=shared_search_paths(app_dirs))
    base.start()
    boot_rows = []
    try:
        for app in POOL_APPS:
            hot = measured[app]["hot_set"]
            pair = measure_boot_pair(app_dirs[app], hot,
                                     shared.delta(app, hot), base)
            boot_rows.append({
                "app": APP_SHORT.get(app, app),
                "boot_fresh_ms": pair["boot_fresh_ms"],
                "boot_shared_ms": pair["boot_shared_ms"],
                "boot_speedup": pair["boot_speedup"],
            })
    finally:
        base.stop()
    print()
    print(table(boot_rows, ["app", "boot_fresh_ms", "boot_shared_ms",
                            "boot_speedup"],
                f"Zygote boot: fresh vs forked from shared base (base "
                f"pre-imports {','.join(shared.modules) or 'nothing'})"))

    # -------------------------------------------- part 2: fleet simulation
    sim_rows = []
    for app in POOL_APPS:
        m = measured[app]
        profile = AppProfile.from_stats(m["fresh"], m["hot_pool"])
        import json as _json
        meta = _json.load(open(os.path.join(root, "apps", app,
                                            "meta.json")))
        traces = standard_traces(app, list(meta["handlers"]),
                                 duration_s=TRACE_DURATION_S)
        policies = default_policies({app: m["report"]},
                                    rate_hint_per_s=1.0)
        for pol in policies:
            for trace in traces.values():
                rep = FleetSimulator(profile, copy.deepcopy(pol)).run(trace)
                s = rep.summary()
                s["app"] = APP_SHORT.get(app, app)
                sim_rows.append(s)
    print()
    print(table(sim_rows, ["app", "policy", "trace", "requests",
                           "cold_starts", "cold_ratio", "p50_ms", "p99_ms",
                           "memory_gb_s", "max_instances"],
                "Keep-alive policy sweep (simulated fleet)"))

    payload = {
        "claim": "fork-pool warm starts >=2x faster than fresh cold "
                 "starts; profile-guided policy trades memory for "
                 "cold-start ratio; shared-base forks boot zygotes "
                 "faster than fresh interpreter boots",
        "pool_rows": rows,
        "boot_rows": boot_rows,
        "shared_modules": list(shared.modules),
        "sim_rows": sim_rows,
        "min_speedup_hot": min(r["speedup_hot"] for r in rows),
        "min_boot_speedup": min(r["boot_speedup"] for r in boot_rows),
        "trace_shapes": sorted({r["trace"] for r in sim_rows}),
    }
    save_result("bench_pool_policies", payload)
    return payload


if __name__ == "__main__":
    run()
