"""Multi-app fleet benchmark: Azure-style trace replay through the
simulated FleetManager and the real zygote fleet.

The north-star claim this covers: at *equal memory budget*, the
profile-guided fleet policy (zygote per app pre-importing the measured
hot set, Little's-law prewarm, amortization-ranked eviction) beats the
fixed-size and idle-timeout baselines on cold-start ratio, with per-app
p99 and budget utilization reported.

Three parts:

1. **Measure** — run the SLIMSTART pipeline per app to get its report
   and hot set, then fresh-process vs hot-fork-pool cold starts to build
   the per-app :class:`AppProfile` (cold/fork init, invoke, RSS).
2. **Simulate** — generate an Azure Functions-style trace (per-minute
   counts, heavy-tailed app popularity, diurnal modulation) over the
   measured apps and replay it under every keep-alive policy at the same
   budget via :func:`repro.pool.fleet.fleet_sweep` — once unbounded
   (the headline cold-start-ratio claim) and once under the daemon's
   bounded queues (``QueueConfig``), reporting shed rate and queue-wait
   p99 alongside the cold-start ratio.  The bounded profile-guided run
   is saved as a schema-versioned ``fleet_summary`` artifact
   (``results/fleet_summary.json``, uploaded nightly).
3. **Replay for real** — boot a :class:`ZygoteFleet` (one zygote per
   app under the budget) and push a slice of the same trace through
   ``dispatch``, reporting measured pool vs cold init latencies.

``--smoke`` (or ``BENCH_QUICK=1``) shrinks everything for CI: fewer
apps, fewer cold starts, shorter trace, a small real-replay slice.
"""

from __future__ import annotations

import argparse
import copy
import json
import math
import os

from repro.api import SlimStart, save_fleet_summary
from repro.benchsuite.genlibs import build_suite
from repro.benchsuite.harness import measure_cold_starts, measure_pool_starts
from repro.pool.fleet import (
    FleetManager, QueueConfig, ZygoteFleet, fleet_sweep,
)
from repro.pool.policies import default_policies, hot_set_from_report
from repro.pool.simulator import AppProfile
from repro.pool.trace import azure_synthetic_rows, trace_from_azure_rows

from benchmarks.common import (
    APP_SHORT, N_COLD, N_INSTANCES, N_INVOKE, QUICK, RESULTS, bench,
    save_result, table,
)

FLEET_APPS = ["graph_bfs", "sentiment_analysis_r", "graph_mst"]
SMOKE_APPS = ["graph_bfs", "sentiment_analysis_r"]


def measure_apps(root: str, apps: list[str], *, instances: int,
                 invocations: int, n_cold: int) -> dict:
    """Pipeline + harness measurements per app -> profiles/reports."""
    measured = {}
    for app in apps:
        res = SlimStart.profile_guided(
            app, root, instances=instances, invocations=invocations).run()
        hot = hot_set_from_report(res.report)
        app_dir = os.path.join(root, "apps", app)
        fresh = measure_cold_starts(app_dir, n=n_cold)
        warm = measure_pool_starts(app_dir, n=n_cold, preload=hot)
        measured[app] = {
            "report": res.report,
            "hot_set": hot,
            "profile": AppProfile.from_stats(fresh, warm),
        }
    return measured


def build_fleet_trace(root: str, apps: list[str], *, minutes: int,
                      peak_rpm: float, seed: int = 11):
    """Azure-shaped trace over the suite apps with their real handlers."""
    handlers = {}
    for app in apps:
        meta = json.load(open(os.path.join(root, "apps", app,
                                           "meta.json")))
        handlers[app] = list(meta["handlers"])
    rows = azure_synthetic_rows(
        apps, minutes=minutes, peak_rpm=peak_rpm, popularity_s=1.3,
        diurnal_period_min=minutes, seed=seed, handlers=handlers)
    return trace_from_azure_rows(rows, seed=seed + 1, name="azure")


@bench("fleet", ref="fleet scale", order=100)
def run(smoke: bool = False) -> dict:
    smoke = smoke or QUICK
    apps = SMOKE_APPS if smoke else FLEET_APPS
    minutes = 10 if smoke else 30
    peak_rpm = 20.0 if smoke else 40.0
    real_limit = 10 if smoke else 40

    root = build_suite()

    # ------------------------------------------------ part 1: measurement
    measured = measure_apps(
        root, apps, instances=max(1, N_INSTANCES // 2),
        invocations=N_INVOKE, n_cold=N_COLD)
    profiles = {a: m["profile"] for a, m in measured.items()}
    reports = {a: m["report"] for a, m in measured.items()}
    prof_rows = [{
        "app": APP_SHORT.get(a, a),
        "cold_init_ms": round(p.cold_init_ms, 1),
        "fork_init_ms": round(p.warm_init_ms, 1),
        "invoke_ms": round(p.invoke_ms, 1),
        "rss_mb": round(p.rss_mb, 1),
        "zygote_rss_mb": round(p.zygote_rss_mb, 1),
        "hot_set": ",".join(measured[a]["hot_set"]),
    } for a, p in profiles.items()]
    print(table(prof_rows, ["app", "cold_init_ms", "fork_init_ms",
                            "invoke_ms", "rss_mb", "zygote_rss_mb",
                            "hot_set"],
                "Measured per-app fleet profiles"))

    # equal budget for every policy: ~1.2x one warm instance per app —
    # tight enough that arbitration decides who stays warm (fixed-size
    # wants 2/app and must leave someone cold), with enough margin that
    # RSS measurement noise can't flip zygote admission run-to-run
    budget_mb = 1.2 * sum(p.rss_mb for p in profiles.values())

    # ------------------------------------------------ part 2: simulation
    trace = build_fleet_trace(root, apps, minutes=minutes,
                              peak_rpm=peak_rpm)
    mean_rate = len(trace) / trace.duration_s
    policies = default_policies(reports, rate_hint_per_s=mean_rate
                                / max(len(apps), 1))
    summaries = fleet_sweep(profiles, policies, trace,
                            budget_mb=budget_mb,
                            policy_factory=copy.deepcopy)
    sim_rows = [s.summary() for s in summaries]
    print()
    print(table(sim_rows, ["policy", "requests", "cold_starts",
                           "cold_ratio", "pool_starts", "p99_ms",
                           "mean_ms", "budget_util", "evictions",
                           "zygotes"],
                f"Fleet policy sweep on Azure-style trace "
                f"(budget {budget_mb:.0f} MB, {len(trace)} requests)"))
    app_rows = []
    for s in summaries:
        for row in s.app_rows():
            app_rows.append({"policy": s.policy, **row,
                             "app": APP_SHORT.get(row["app"], row["app"])})
    print()
    print(table(app_rows, ["policy", "app", "requests", "cold_starts",
                           "cold_ratio", "p50_ms", "p99_ms",
                           "memory_gb_s", "max_instances"],
                "Per-app breakdown (paper-style per-application rows)"))

    by_policy = {s.policy: s for s in summaries}
    pg = by_policy["profile-guided"]
    beats_fixed = pg.cold_start_ratio < by_policy["fixed"].cold_start_ratio
    beats_idle = (pg.cold_start_ratio
                  < by_policy["idle-timeout"].cold_start_ratio)

    # ------------------------------- part 2b: bounded queues (daemon mode)
    # the same trace under the serve daemon's backpressure config:
    # demand spawns stop at max_concurrency, overload queues (bounded)
    # and sheds — the shed rate and queue-wait p99 are the cost of
    # bounding memory that the unbounded sweep above never pays
    queue_cfg = QueueConfig(depth=8, max_concurrency=2,
                            shed_policy="reject-new")
    queue_rows = []
    queue_summaries = {}
    for pol in default_policies(reports, rate_hint_per_s=mean_rate
                                / max(len(apps), 1)):
        s = FleetManager(profiles, copy.deepcopy(pol),
                         budget_mb=budget_mb,
                         queue=queue_cfg).replay(trace)
        queue_summaries[s.policy] = s
        queue_rows.append({
            "policy": s.policy,
            "requests": s.n_requests,
            "served": s.served,
            "cold_ratio": round(s.cold_start_ratio, 4),
            "sheds": s.sheds,
            "shed_rate": round(s.sheds / max(s.n_requests, 1), 4),
            "queue_wait_p99_ms": round(s.queue_wait_p99_ms, 2)
            if not math.isnan(s.queue_wait_p99_ms) else 0.0,
            "p99_ms": round(s.p99_ms, 2),
        })
    print()
    print(table(queue_rows, ["policy", "requests", "served",
                             "cold_ratio", "sheds", "shed_rate",
                             "queue_wait_p99_ms", "p99_ms"],
                f"Bounded-queue sweep (depth={queue_cfg.depth}, "
                f"max_concurrency={queue_cfg.max_concurrency}, "
                f"{queue_cfg.shed_policy})"))
    fleet_summary_path = save_fleet_summary(
        queue_summaries["profile-guided"].artifact_payload(
            source="bench"),
        str(RESULTS / "fleet_summary.json"),
        meta={"bench": "bench_fleet", "smoke": bool(smoke)})
    print(f"fleet_summary artifact: {fleet_summary_path}")

    # ------------------------------------------------ part 3: real replay
    app_dirs = {a: os.path.join(root, "apps", a) for a in apps}
    with ZygoteFleet(app_dirs, budget_mb=budget_mb,
                     reports=reports) as fleet:
        boot = {"zygotes": sorted(fleet.servers),
                "skipped": list(fleet.skipped),
                "used_mb": round(fleet.used_mb(), 1)}
        real_rows = fleet.replay(trace, limit=real_limit)
    print()
    print(table(real_rows, ["app", "requests", "pool_starts",
                            "cold_starts", "cold_ratio", "pool_init_ms",
                            "cold_init_ms"],
                f"Real zygote-fleet replay (first {real_limit} requests; "
                f"zygotes: {','.join(boot['zygotes'])}; "
                f"{boot['used_mb']} MB resident)"))

    verdict = ("profile-guided fleet beats fixed-size and idle-timeout "
               "on cold-start ratio at equal budget"
               if beats_fixed and beats_idle else
               "WARNING: profile-guided did NOT beat both baselines")
    print(f"\n{verdict}")

    payload = {
        "claim": "at equal memory budget the profile-guided fleet "
                 "policy has the lowest cold-start ratio, with per-app "
                 "p99 and budget utilization reported",
        "budget_mb": round(budget_mb, 1),
        "trace": {"shape": "azure", "requests": len(trace),
                  "duration_s": trace.duration_s,
                  "apps": {a: sum(1 for r in trace if r.app == a)
                           for a in apps}},
        "profile_rows": prof_rows,
        "sim_rows": sim_rows,
        "queue_rows": queue_rows,
        "queue_config": queue_cfg.to_dict(),
        "per_app_rows": app_rows,
        "real_boot": boot,
        "real_rows": real_rows,
        "profile_guided_beats_fixed": beats_fixed,
        "profile_guided_beats_idle_timeout": beats_idle,
    }
    save_result("bench_fleet", payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer apps, shorter trace")
    args = ap.parse_args()
    run(smoke=args.smoke)
