"""Multi-app fleet benchmark: Azure-style trace replay through the
simulated FleetManager and the real zygote fleet.

The north-star claim this covers: at *equal memory budget*, the
profile-guided fleet policy (zygote per app pre-importing the measured
hot set, Little's-law prewarm, amortization-ranked eviction) beats the
fixed-size and idle-timeout baselines on cold-start ratio, with per-app
p99 and budget utilization reported.

Three parts:

1. **Measure** — run the SLIMSTART pipeline per app to get its report
   and hot set, then fresh-process vs hot-fork-pool cold starts to build
   the per-app :class:`AppProfile` (cold/fork init, invoke, RSS).
2. **Simulate** — generate an Azure Functions-style trace (per-minute
   counts, heavy-tailed app popularity, diurnal modulation) over the
   measured apps and replay it under every keep-alive policy at the same
   budget via :func:`repro.pool.fleet.fleet_sweep` — once unbounded
   (the headline cold-start-ratio claim) and once under the daemon's
   bounded queues (``QueueConfig``), reporting shed rate and queue-wait
   p99 alongside the cold-start ratio.  The bounded profile-guided run
   is saved as a schema-versioned ``fleet_summary`` artifact
   (``results/fleet_summary.json``, uploaded nightly).
3. **Replay for real** — boot a :class:`ZygoteFleet` (one zygote per
   app under the budget) and push a slice of the same trace through
   ``dispatch``, reporting measured pool vs cold init latencies.

``--smoke`` (or ``BENCH_QUICK=1``) shrinks everything for CI: fewer
apps, fewer cold starts, shorter trace, a small real-replay slice.
"""

from __future__ import annotations

import argparse
import copy
import dataclasses
import json
import math
import os
import time

from repro.api import SlimStart, save_cluster_summary, save_fleet_summary
from repro.benchsuite.genlibs import build_suite
from repro.cluster import compare_strategies, synthetic_cluster_workload
from repro.benchsuite.harness import measure_cold_starts, measure_pool_starts
from repro.pool.fleet import (
    FleetManager, QueueConfig, ZygoteFleet, fleet_sweep,
)
from repro.pool.forkserver import BaseZygote
from repro.pool.policies import default_policies, hot_set_from_report
from repro.pool.sharing import compute_shared_hot_set, shared_search_paths
from repro.pool.simulator import AppProfile
from repro.pool.trace import azure_synthetic_rows, trace_from_azure_rows

from benchmarks.common import (
    APP_SHORT, N_COLD, N_INSTANCES, N_INVOKE, QUICK, RESULTS, bench,
    measure_boot_pair, save_result, table,
)

FLEET_APPS = ["graph_bfs", "sentiment_analysis_r", "graph_mst"]
# the smoke pair must share a library (both vendor fakelib_igraph), or
# CI/nightly would measure the two-tier fleet with an empty shared
# base and a shared-set regression could never move the trajectory
SMOKE_APPS = ["graph_bfs", "graph_mst"]


def measure_apps(root: str, apps: list[str], *, instances: int,
                 invocations: int, n_cold: int) -> dict:
    """Pipeline + harness measurements per app -> profiles/reports."""
    measured = {}
    for app in apps:
        res = SlimStart.profile_guided(
            app, root, instances=instances, invocations=invocations).run()
        hot = hot_set_from_report(res.report)
        app_dir = os.path.join(root, "apps", app)
        fresh = measure_cold_starts(app_dir, n=n_cold)
        warm = measure_pool_starts(app_dir, n=n_cold, preload=hot)
        measured[app] = {
            "report": res.report,
            "hot_set": hot,
            "profile": AppProfile.from_stats(fresh, warm),
        }
    return measured


def measure_two_tier_boot(root: str, apps: list[str],
                          measured: dict) -> dict:
    """PR 5's headline measurement: per-app zygote boot latency and
    incremental memory, one-zygote-per-app (PR 2: fresh interpreter +
    hot-set import each) vs two-tier (fork from the shared base +
    private delta import)."""
    app_dirs = {a: os.path.join(root, "apps", a) for a in apps}
    reports = {a: m["report"] for a, m in measured.items()}
    shared = compute_shared_hot_set(reports, min_apps=2)
    base = BaseZygote(preload=shared.modules,
                      search_paths=shared_search_paths(app_dirs))
    t0 = time.perf_counter()
    base.start()
    base_boot_ms = (time.perf_counter() - t0) * 1e3
    base_rss_mb = base.rss_kb() / 1024.0
    rows = []
    try:
        for app in apps:
            hot = measured[app]["hot_set"]
            delta = shared.delta(app, hot)
            pair = measure_boot_pair(app_dirs[app], hot, delta, base)
            rows.append({
                "app": APP_SHORT.get(app, app),
                "boot_fresh_ms": pair["boot_fresh_ms"],
                "boot_shared_ms": pair["boot_shared_ms"],
                "boot_speedup": pair["boot_speedup"],
                "delta": ",".join(delta) or "-",
                "zygote_rss_mb": pair["fresh_rss_mb"],
                "incremental_mb": pair["incremental_mb"],
            })
    finally:
        base.stop()
    return {
        "shared_modules": list(shared.modules),
        "base_boot_ms": round(base_boot_ms, 1),
        "base_rss_mb": round(base_rss_mb, 1),
        "rows": rows,
        "incremental_mb": {apps[i]: rows[i]["incremental_mb"]
                           for i in range(len(apps))},
        "min_boot_speedup": min(r["boot_speedup"] for r in rows),
    }


def build_fleet_trace(root: str, apps: list[str], *, minutes: int,
                      peak_rpm: float, seed: int = 11):
    """Azure-shaped trace over the suite apps with their real handlers."""
    handlers = {}
    for app in apps:
        meta = json.load(open(os.path.join(root, "apps", app,
                                           "meta.json")))
        handlers[app] = list(meta["handlers"])
    rows = azure_synthetic_rows(
        apps, minutes=minutes, peak_rpm=peak_rpm, popularity_s=1.3,
        diurnal_period_min=minutes, seed=seed, handlers=handlers)
    return trace_from_azure_rows(rows, seed=seed + 1, name="azure")


def run_adaptive_comparison(*, smoke: bool = False,
                            seed: int = 23) -> dict:
    """Static vs closed-loop adaptive replay of a popularity-flip
    trace (self-contained; also used by tools/record_bench.py)."""
    from repro.api import save_drift_report
    from repro.core.adaptive import AdaptiveConfig, DriftConfig
    from repro.core.profiler.report import OptimizationReport
    from repro.core.profiler.utilization import LibraryStats
    from repro.pool.daemon import make_sim_adaptive_loop
    from repro.pool.policies import ProfileGuidedPolicy
    from repro.pool.trace import azure_flip_trace

    apps = ["flip_head", "flip_mid", "flip_tail"]
    # lean zygotes (copy-on-write incremental pages) so mid-run zygote
    # admission for the newly-hot app doesn't evict serving instances
    profiles = {a: AppProfile(app=a, cold_init_ms=400.0,
                              warm_init_ms=40.0, invoke_ms=30.0,
                              rss_mb=128.0, zygote_rss_mb=32.0)
                for a in apps}
    minutes = 10 if smoke else 20
    trace = azure_flip_trace(apps, minutes=minutes, peak_rpm=60.0,
                             popularity_s=2.0, seed=seed)
    budget_mb = 2.0 * sum(p.rss_mb for p in profiles.values())

    def synth_report(app: str) -> OptimizationReport:
        prof = profiles[app]
        e2e = (prof.cold_init_ms + prof.invoke_ms) / 1e3
        init = 0.8 * prof.cold_init_ms / 1e3
        return OptimizationReport(
            application=app, e2e_s=e2e, total_init_s=init,
            qualifies=True,
            stats=[LibraryStats(name=f"simlib_{app}", utilization=0.9,
                                init_s=init, init_share=init / e2e,
                                runtime_samples=50, file="<sim>")],
            defer_targets=[])

    def yesterday_policy() -> ProfileGuidedPolicy:
        policy = ProfileGuidedPolicy()
        for a in apps[:-1]:  # the post-flip head app was never profiled
            policy.add_report(synth_report(a))
        return policy

    static = FleetManager(profiles, yesterday_policy(),
                          budget_mb=budget_mb).replay(trace)

    manager = FleetManager(profiles, yesterday_policy(),
                           budget_mb=budget_mb)
    loop = make_sim_adaptive_loop(
        manager, config=AdaptiveConfig(drift=DriftConfig(window_s=120.0)))
    manager.begin(trace.name)
    for req in trace:
        loop.observe_request(req.app, req.handler, t=req.t)
        manager.offer(req)
    adaptive = manager.finish(trace.duration_s)
    loop.flush(t=trace.duration_s)

    def _p99_init_ms(s) -> float:
        # the exact init-latency multiset is recoverable from the
        # summary's path counts: cold spawns pay the full init, pool
        # (zygote-fork) starts the fork init, warm reuse none
        samples = ([profiles[apps[0]].cold_init_ms] * s.cold_starts
                   + [profiles[apps[0]].warm_init_ms] * s.pool_starts
                   + [0.0] * max(s.served - s.cold_starts
                                 - s.pool_starts, 0))
        samples.sort()
        return (samples[min(int(0.99 * len(samples)),
                            len(samples) - 1)] if samples else 0.0)

    def _row(mode, s, fires, reopt):
        return {"mode": mode, "requests": s.n_requests,
                "cold_starts": s.cold_starts,
                "cold_ratio": round(s.cold_start_ratio, 4),
                "p99_init_ms": round(_p99_init_ms(s), 2),
                "p99_ms": round(s.p99_ms, 2),
                "mean_ms": round(s.mean_ms, 2),
                "drift_fires": fires, "reoptimized": reopt}

    reoptimized = sorted({a["app"] for act in loop.actions
                          for a in act.get("applied", [])})
    rows = [
        _row("static (yesterday's reports)", static, 0, "-"),
        _row("adaptive closed loop", adaptive, loop.detector.fires,
             ",".join(reoptimized) or "-"),
    ]
    drift_path = save_drift_report(
        loop.drift_report_payload(source="bench"),
        str(RESULTS / "drift_report.json"),
        meta={"bench": "bench_fleet", "smoke": bool(smoke)})
    beats = (adaptive.cold_start_ratio < static.cold_start_ratio
             and _p99_init_ms(adaptive) <= _p99_init_ms(static)
             and loop.detector.fires >= 1)
    return {
        "rows": rows,
        "trace_requests": len(trace),
        "flip_s": minutes * 30.0,
        "drift_report_path": drift_path,
        "static_cold_ratio": round(static.cold_start_ratio, 4),
        "adaptive_cold_ratio": round(adaptive.cold_start_ratio, 4),
        "static_p99_init_ms": round(_p99_init_ms(static), 2),
        "adaptive_p99_init_ms": round(_p99_init_ms(adaptive), 2),
        "static_p99_ms": round(static.p99_ms, 2),
        "adaptive_p99_ms": round(adaptive.p99_ms, 2),
        "drift_fires": loop.detector.fires,
        "adaptive_beats_static": beats,
    }


def run_handoff_comparison(root: str, apps: list[str],
                           reports: dict) -> dict:
    """Warm-state handoff vs cold re-place on the real tier (ISSUE
    10): both arms are a successor node that deploys the app but was
    not serving it.  The warm arm runs
    :meth:`~repro.pool.fleet.ZygoteFleet.prewarm_app` with the
    departing owner's shipped report BEFORE the first request lands —
    exactly what the router's ``plan_leave`` prewarm exchange triggers
    on the target — so that request forks from a hot zygote; the cold
    arm (unplanned loss / stalled handoff) pays a full fresh-process
    cold start.  The number that matters is the app's FIRST request on
    its new owner."""
    rows = []
    for app in apps:
        app_dir = {app: os.path.join(root, "apps", app)}
        fleet = ZygoteFleet(app_dir, reports={app: reports[app]})
        try:
            m_cold = fleet.dispatch(app, seed=901)
        finally:
            fleet.stop()
        fleet = ZygoteFleet(app_dir, reports={app: reports[app]})
        try:
            pre = fleet.prewarm_app(app, report=reports[app])
            m_warm = fleet.dispatch(app, seed=901)
        finally:
            fleet.stop()
        rows.append({
            "app": APP_SHORT.get(app, app),
            "cold_first_ms": round(m_cold["init_ms"], 1),
            "warm_first_ms": round(m_warm["init_ms"], 1),
            "speedup": round(m_cold["init_ms"]
                             / max(m_warm["init_ms"], 1e-9), 2),
            "prewarmed": bool(pre.get("warm")),
            "cold_path": m_cold["path"],
            "warm_path": m_warm["path"],
        })
    beats = all(r["prewarmed"] and r["warm_path"] == "pool"
                and r["cold_path"] == "cold"
                and r["warm_first_ms"] < r["cold_first_ms"]
                for r in rows)
    return {"rows": rows, "warm_beats_cold": beats,
            "min_speedup": min((r["speedup"] for r in rows),
                               default=0.0)}


@bench("fleet", ref="fleet scale", order=100)
def run(smoke: bool = False) -> dict:
    smoke = smoke or QUICK
    apps = SMOKE_APPS if smoke else FLEET_APPS
    minutes = 10 if smoke else 30
    peak_rpm = 20.0 if smoke else 40.0
    real_limit = 10 if smoke else 40

    root = build_suite()

    # ------------------------------------------------ part 1: measurement
    measured = measure_apps(
        root, apps, instances=max(1, N_INSTANCES // 2),
        invocations=N_INVOKE, n_cold=N_COLD)
    profiles = {a: m["profile"] for a, m in measured.items()}
    reports = {a: m["report"] for a, m in measured.items()}
    prof_rows = [{
        "app": APP_SHORT.get(a, a),
        "cold_init_ms": round(p.cold_init_ms, 1),
        "fork_init_ms": round(p.warm_init_ms, 1),
        "invoke_ms": round(p.invoke_ms, 1),
        "rss_mb": round(p.rss_mb, 1),
        "zygote_rss_mb": round(p.zygote_rss_mb, 1),
        "hot_set": ",".join(measured[a]["hot_set"]),
    } for a, p in profiles.items()]
    print(table(prof_rows, ["app", "cold_init_ms", "fork_init_ms",
                            "invoke_ms", "rss_mb", "zygote_rss_mb",
                            "hot_set"],
                "Measured per-app fleet profiles"))

    # ---------------------------------------- part 1b: two-tier zygote boot
    two_tier = measure_two_tier_boot(root, apps, measured)
    print()
    print(table(two_tier["rows"],
                ["app", "boot_fresh_ms", "boot_shared_ms",
                 "boot_speedup", "delta", "zygote_rss_mb",
                 "incremental_mb"],
                f"Per-app zygote boot: fresh interpreter vs fork from "
                f"shared base (base pre-imports "
                f"{','.join(two_tier['shared_modules']) or 'nothing'}, "
                f"boots once in {two_tier['base_boot_ms']} ms, "
                f"{two_tier['base_rss_mb']} MB resident)"))

    # equal budget for every policy: ~1.2x one warm instance per app —
    # tight enough that arbitration decides who stays warm (fixed-size
    # wants 2/app and must leave someone cold), with enough margin that
    # RSS measurement noise can't flip zygote admission run-to-run
    budget_mb = 1.2 * sum(p.rss_mb for p in profiles.values())

    # ------------------------------------------------ part 2: simulation
    trace = build_fleet_trace(root, apps, minutes=minutes,
                              peak_rpm=peak_rpm)
    mean_rate = len(trace) / trace.duration_s
    policies = default_policies(reports, rate_hint_per_s=mean_rate
                                / max(len(apps), 1))
    summaries = fleet_sweep(profiles, policies, trace,
                            budget_mb=budget_mb,
                            policy_factory=copy.deepcopy)
    sim_rows = [s.summary() for s in summaries]
    print()
    print(table(sim_rows, ["policy", "requests", "cold_starts",
                           "cold_ratio", "pool_starts", "p99_ms",
                           "mean_ms", "budget_util", "evictions",
                           "zygotes"],
                f"Fleet policy sweep on Azure-style trace "
                f"(budget {budget_mb:.0f} MB, {len(trace)} requests)"))
    app_rows = []
    for s in summaries:
        for row in s.app_rows():
            app_rows.append({"policy": s.policy, **row,
                             "app": APP_SHORT.get(row["app"], row["app"])})
    print()
    print(table(app_rows, ["policy", "app", "requests", "cold_starts",
                           "cold_ratio", "p50_ms", "p99_ms",
                           "memory_gb_s", "max_instances"],
                "Per-app breakdown (paper-style per-application rows)"))

    by_policy = {s.policy: s for s in summaries}
    pg = by_policy["profile-guided"]
    beats_fixed = pg.cold_start_ratio < by_policy["fixed"].cold_start_ratio
    beats_idle = (pg.cold_start_ratio
                  < by_policy["idle-timeout"].cold_start_ratio)

    # -------------------------------- part 2a: shared-base sim comparison
    # the same profile-guided replay with the measured two-tier numbers:
    # the base's RSS is charged once fleet-wide and each zygote only its
    # measured incremental pages — the memory GB-s axis of the paper's
    # 1.51X claim, at fleet scale
    shared_profiles = {
        a: dataclasses.replace(
            p, zygote_private_mb=two_tier["incremental_mb"].get(a, 0.0))
        for a, p in profiles.items()}
    # the sweep above ran deepcopies, so the panel's profile-guided
    # policy is unpolluted and reusable here
    pg_policy = next(p for p in policies if p.name == "profile-guided")
    shared_sim = FleetManager(
        shared_profiles, copy.deepcopy(pg_policy), budget_mb=budget_mb,
        shared_base_mb=two_tier["base_rss_mb"]).replay(trace)
    # the claim is "lower memory GB-s at EQUAL cold-start ratio": when
    # the two-tier fleet serves strictly better at the same budget,
    # grow the one-per-app budget until it serves as well, and compare
    # memory there — that run is what PR 2 would actually have to pay
    # for the service level the shared base delivers
    eq, eq_budget = pg, budget_mb
    while (eq.cold_start_ratio > shared_sim.cold_start_ratio
           and eq_budget < 4.0 * budget_mb):
        eq_budget *= 1.15
        eq = FleetManager(profiles, copy.deepcopy(pg_policy),
                          budget_mb=eq_budget).replay(trace)

    def _fleet_row(name, s):
        return {"fleet": name,
                "cold_ratio": round(s.cold_start_ratio, 4),
                "memory_gb_s": round(s.memory_mb_s / 1024.0, 3),
                "p99_ms": round(s.p99_ms, 2),
                "zygotes": len(s.zygote_apps)}

    shared_rows = [
        _fleet_row("one-zygote-per-app (PR 2)", pg),
        _fleet_row("shared-base two-tier", shared_sim),
    ]
    if eq is not pg:
        shared_rows.insert(1, _fleet_row(
            f"one-zygote-per-app @ equal service "
            f"(budget {eq_budget:.0f} MB)", eq))
    print()
    print(table(shared_rows, ["fleet", "cold_ratio", "memory_gb_s",
                              "p99_ms", "zygotes"],
                f"Profile-guided fleet, one-per-app vs shared base "
                f"(base {two_tier['base_rss_mb']} MB charged once, "
                f"budget {budget_mb:.0f} MB)"))
    shared_base_wins = (
        two_tier["min_boot_speedup"] >= 1.3
        and shared_sim.memory_mb_s < eq.memory_mb_s
        and shared_sim.cold_start_ratio <= eq.cold_start_ratio)

    # ------------------------------- part 2b: bounded queues (daemon mode)
    # the same trace under the serve daemon's backpressure config:
    # demand spawns stop at max_concurrency, overload queues (bounded)
    # and sheds — the shed rate and queue-wait p99 are the cost of
    # bounding memory that the unbounded sweep above never pays
    queue_cfg = QueueConfig(depth=8, max_concurrency=2,
                            shed_policy="reject-new")
    queue_rows = []
    queue_summaries = {}
    for pol in default_policies(reports, rate_hint_per_s=mean_rate
                                / max(len(apps), 1)):
        s = FleetManager(profiles, copy.deepcopy(pol),
                         budget_mb=budget_mb,
                         queue=queue_cfg).replay(trace)
        queue_summaries[s.policy] = s
        queue_rows.append({
            "policy": s.policy,
            "requests": s.n_requests,
            "served": s.served,
            "cold_ratio": round(s.cold_start_ratio, 4),
            "sheds": s.sheds,
            "shed_rate": round(s.sheds / max(s.n_requests, 1), 4),
            "queue_wait_p99_ms": round(s.queue_wait_p99_ms, 2)
            if not math.isnan(s.queue_wait_p99_ms) else 0.0,
            "p99_ms": round(s.p99_ms, 2),
        })
    print()
    print(table(queue_rows, ["policy", "requests", "served",
                             "cold_ratio", "sheds", "shed_rate",
                             "queue_wait_p99_ms", "p99_ms"],
                f"Bounded-queue sweep (depth={queue_cfg.depth}, "
                f"max_concurrency={queue_cfg.max_concurrency}, "
                f"{queue_cfg.shed_policy})"))
    fleet_summary_path = save_fleet_summary(
        queue_summaries["profile-guided"].artifact_payload(
            source="bench"),
        str(RESULTS / "fleet_summary.json"),
        meta={"bench": "bench_fleet", "smoke": bool(smoke)})
    print(f"fleet_summary artifact: {fleet_summary_path}")

    # ------------------------------ part 2d: adaptive closed loop (ISSUE 9)
    # mid-trace popularity flip: "static" is the profile-guided fleet
    # tuned for yesterday's workload — reports deployed only for the
    # pre-flip head apps, so the post-flip head app has no zygote and
    # no prewarm floor.  "adaptive" runs the *same* starting policy
    # plus the closed loop: live drift windows over the arrival mix, a
    # noise-calibrated trigger, and in-process re-optimization that
    # deploys a fresh report for the newly-hot app mid-replay.
    adaptive_cmp = run_adaptive_comparison(smoke=smoke)
    print()
    print(table(adaptive_cmp["rows"],
                ["mode", "requests", "cold_starts", "cold_ratio",
                 "p99_init_ms", "p99_ms", "mean_ms", "drift_fires",
                 "reoptimized"],
                f"Closed-loop adaptive vs static on a popularity-flip "
                f"trace ({adaptive_cmp['trace_requests']} requests, "
                f"flip at t={adaptive_cmp['flip_s']:.0f}s)"))

    # --------------------------- part 2c: cluster placement comparison
    # scale out: the same trace shape sharded over N simulated nodes
    # (per-node budgets, per-node shared bases), replayed once per
    # placement strategy at equal total memory.  The ISSUE-8 claim:
    # sharing-aware placement packs library families onto the same
    # node, so each node's base zygote covers more pages, more zygotes
    # fit, and the cluster-wide cold-start ratio drops vs plain
    # consistent hashing
    cluster_nodes = 4
    cluster_wl = synthetic_cluster_workload(
        8 if smoke else 16, n_families=cluster_nodes,
        seed=7, minutes=5 if smoke else 20,
        peak_rpm=40.0 if smoke else 80.0)
    cluster_results = compare_strategies(
        cluster_wl, n_nodes=cluster_nodes, node_budget_mb=512.0,
        seed=7, limit=400 if smoke else None)
    cluster_rows = [{
        "placement": strat,
        "requests": p["requests"],
        "cold_starts": p["cold_starts"],
        "cold_ratio": p["cold_start_ratio"],
        "p99_ms": p["p99_ms"],
        "memory_gb_s": p.get("memory_gb_s", 0.0),
        "conserves": p["conservation"]["holds"],
    } for strat, p in cluster_results.items()]
    print()
    print(table(cluster_rows, ["placement", "requests", "cold_starts",
                               "cold_ratio", "p99_ms", "memory_gb_s",
                               "conserves"],
                f"Cluster placement comparison ({cluster_nodes} nodes "
                f"x 512 MB, {len(cluster_wl.apps)} apps in "
                f"{cluster_nodes} library families, Zipf trace)"))
    cluster_sharing_beats_hash = (
        cluster_results["sharing"]["cold_start_ratio"]
        < cluster_results["hash"]["cold_start_ratio"]
        and all(p["conservation"]["holds"]
                for p in cluster_results.values()))
    save_cluster_summary(
        cluster_results["sharing"],
        str(RESULTS / "cluster_summary.json"),
        meta={"bench": "bench_fleet", "smoke": bool(smoke)})
    print(f"cluster_summary artifact: "
          f"{RESULTS / 'cluster_summary.json'}")

    # ------------------------------------------------ part 3: real replay
    # two-tier for real: the fleet boots its shared base, forks per-app
    # zygotes from it, and the replay dispatches through them
    app_dirs = {a: os.path.join(root, "apps", a) for a in apps}
    with ZygoteFleet(app_dirs, budget_mb=budget_mb, reports=reports,
                     shared_base=True) as fleet:
        boot = {"zygotes": sorted(fleet.servers),
                "skipped": list(fleet.skipped),
                "used_mb": round(fleet.used_mb(), 1),
                **fleet._base_info()}
        real_rows = fleet.replay(trace, limit=real_limit)
    print()
    print(table(real_rows, ["app", "requests", "pool_starts",
                            "cold_starts", "cold_ratio", "pool_init_ms",
                            "cold_init_ms"],
                f"Real shared-base fleet replay (first {real_limit} "
                f"requests; zygotes: {','.join(boot['zygotes'])}; "
                f"{boot['used_mb']} MB incremental-resident)"))

    # ------------------------------ part 3b: warm handoff vs cold re-place
    handoff_cmp = run_handoff_comparison(root, apps, reports)
    print()
    print(table(handoff_cmp["rows"],
                ["app", "cold_first_ms", "warm_first_ms", "speedup",
                 "prewarmed", "cold_path", "warm_path"],
                "Planned-migration handoff: first request on the new "
                "owner, prewarmed from the shipped report vs cold "
                "re-place"))

    verdict = ("profile-guided fleet beats fixed-size and idle-timeout "
               "on cold-start ratio at equal budget"
               if beats_fixed and beats_idle else
               "WARNING: profile-guided did NOT beat both baselines")
    verdict2 = (f"shared-base two-tier: >=1.3X faster per-app zygote "
                f"boot (min {two_tier['min_boot_speedup']}X) and lower "
                f"memory GB-s at equal-or-better cold-start ratio"
                if shared_base_wins else
                "WARNING: shared-base two-tier did NOT meet the "
                ">=1.3X boot / lower-memory target")
    verdict4 = (f"adaptive closed loop beats the static fleet on the "
                f"popularity-flip trace: cold ratio "
                f"{adaptive_cmp['adaptive_cold_ratio']} vs "
                f"{adaptive_cmp['static_cold_ratio']}, p99 init "
                f"{adaptive_cmp['adaptive_p99_init_ms']} vs "
                f"{adaptive_cmp['static_p99_init_ms']} ms, "
                f"{adaptive_cmp['drift_fires']} drift fire(s)"
                if adaptive_cmp["adaptive_beats_static"] else
                "WARNING: the adaptive closed loop did NOT beat the "
                "static fleet on the popularity-flip trace")
    verdict3 = (f"cluster: sharing-aware placement beats plain "
                f"consistent hashing on cold-start ratio "
                f"({cluster_results['sharing']['cold_start_ratio']} vs "
                f"{cluster_results['hash']['cold_start_ratio']}) at "
                f"equal total memory, with request conservation on "
                f"every node"
                if cluster_sharing_beats_hash else
                "WARNING: sharing-aware placement did NOT beat plain "
                "hashing (or conservation broke)")
    verdict5 = (f"warm handoff beats cold re-place on the new owner's "
                f"first request for every app (min "
                f"{handoff_cmp['min_speedup']}X)"
                if handoff_cmp["warm_beats_cold"] else
                "WARNING: warm handoff did NOT beat cold re-place on "
                "first-request latency")
    print(f"\n{verdict}\n{verdict2}\n{verdict3}\n{verdict4}\n{verdict5}")

    payload = {
        "claim": "at equal memory budget the profile-guided fleet "
                 "policy has the lowest cold-start ratio, with per-app "
                 "p99 and budget utilization reported; the shared-base "
                 "two-tier fleet boots per-app zygotes >=1.3X faster "
                 "and holds less memory at equal cold-start ratio",
        "budget_mb": round(budget_mb, 1),
        "trace": {"shape": "azure", "requests": len(trace),
                  "duration_s": trace.duration_s,
                  "apps": {a: sum(1 for r in trace if r.app == a)
                           for a in apps}},
        "profile_rows": prof_rows,
        "sim_rows": sim_rows,
        "queue_rows": queue_rows,
        "queue_config": queue_cfg.to_dict(),
        "per_app_rows": app_rows,
        "real_boot": boot,
        "real_rows": real_rows,
        "profile_guided_beats_fixed": beats_fixed,
        "profile_guided_beats_idle_timeout": beats_idle,
        "two_tier_boot": two_tier,
        "shared_base_rows": shared_rows,
        "shared_base_wins": shared_base_wins,
        "cluster_rows": cluster_rows,
        "cluster_nodes": cluster_nodes,
        "cluster_sharing_beats_hash": cluster_sharing_beats_hash,
        "adaptive_rows": adaptive_cmp["rows"],
        "adaptive_comparison": adaptive_cmp,
        "handoff_rows": handoff_cmp["rows"],
        "handoff_min_speedup": handoff_cmp["min_speedup"],
        "handoff_warm_beats_cold": handoff_cmp["warm_beats_cold"],
    }
    save_result("bench_fleet", payload)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: fewer apps, shorter trace")
    args = ap.parse_args()
    run(smoke=args.smoke)
