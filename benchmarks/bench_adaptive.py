"""Fig. 10 — adaptive profiling trigger on a shifting workload.

Replays a piecewise-stationary trace (stable phase, then a distribution
flip) through the Eq. 5-7 monitor with the paper's epsilon = 0.002 and
scaled-down 12 h windows: profiling must NOT trigger while the workload
is stable and MUST trigger right after the shift.
"""

from __future__ import annotations

from repro.benchsuite.workload import ShiftingWorkload
from repro.core.adaptive.monitor import MonitorConfig, WorkloadMonitor

from benchmarks.common import bench, save_result, table


@bench("adaptive", ref="Fig. 10", order=20)
def run() -> dict:
    handlers = [f"h{i}" for i in range(6)]
    window_s = 100.0  # stands in for the paper's 12 h window
    wl = ShiftingWorkload.stable_then_shift(
        handlers, window_s, n_stable=6, n_shifted=4, rate_per_s=50.0,
        seed=3)

    now = {"t": 0.0}
    monitor = WorkloadMonitor(
        MonitorConfig(window_s=window_s, epsilon=0.002),
        clock=lambda: now["t"])
    for t, h in wl.events():
        now["t"] = t
        monitor.record(h)
    monitor.flush()

    rows = [{
        "window_end_s": round(w.t_end, 1),
        "delta_p_sum": round(w.aggregate_change, 4),
        "triggered": w.triggered,
    } for w in monitor.history]

    shift_t = 6 * window_s
    # skip the very first window (no previous distribution yet)
    stable_rows = [r for r in rows if r["window_end_s"] <= shift_t]
    shift_rows = [r for r in rows
                  if shift_t < r["window_end_s"] <= shift_t + 2 * window_s]
    # stable-phase noise stays near zero; the flip dwarfs epsilon
    stable_noise = max((r["delta_p_sum"] for r in stable_rows[1:]),
                       default=0.0)
    shift_delta = max((r["delta_p_sum"] for r in shift_rows),
                      default=0.0)
    # the paper's eps=0.002 targets production volumes (millions of
    # invocations per 12 h window); at this trace's ~5k/window the
    # sampling noise floor is ~0.05, so we also evaluate a
    # noise-calibrated eps = 2x the stable-phase noise
    eps_cal = 2 * stable_noise
    payload = {
        "figure": "Fig. 10",
        "epsilon_paper": 0.002,
        "epsilon_calibrated": round(eps_cal, 4),
        "claims": {
            "stable_phase_max_delta": stable_noise,
            "shift_delta": shift_delta,
            "shift_detected": any(r["triggered"] for r in shift_rows),
            "shift_to_noise_ratio": round(
                shift_delta / max(stable_noise, 1e-9), 1),
            "n_triggers_paper_eps": monitor.triggers,
            "calibrated_stable_quiet": all(
                r["delta_p_sum"] <= eps_cal for r in stable_rows[1:]),
            "calibrated_shift_detected": any(
                r["delta_p_sum"] > eps_cal for r in shift_rows),
        },
        "rows": rows,
    }
    save_result("bench_adaptive", payload)
    print(table(rows, ["window_end_s", "delta_p_sum", "triggered"],
                "Fig. 10 adaptive trigger"))
    print(f"shift detected: {payload['claims']['shift_detected']}; "
          f"shift/noise = {payload['claims']['shift_to_noise_ratio']}x")
    return payload


if __name__ == "__main__":
    run()
